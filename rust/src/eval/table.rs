//! Table/CSV output helpers for the experiment harness.

use std::fmt::Write as _;
use std::path::Path;

/// A result table: printed as markdown, persisted as CSV.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub id: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(id: impl Into<String>, title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            id: id.into(),
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {} — {}", self.id, self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(line, " {c:<w$} |");
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<width$}|", "", width = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV.
    pub fn csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(esc).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write `<dir>/<id>.csv`; creates the directory.
    pub fn save_csv(&self, dir: &Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.id));
        std::fs::write(&path, self.csv())?;
        Ok(path)
    }

    /// Render as a JSON object (via the [`crate::eval::report`] layer):
    /// `{"id":...,"title":...,"headers":[...],"rows":[[...],...]}`.
    pub fn json(&self) -> String {
        use crate::eval::report::{escape, json_array, JsonObj};
        let headers =
            json_array(self.headers.iter().map(|h| format!("\"{}\"", escape(h))));
        let rows = json_array(self.rows.iter().map(|row| {
            json_array(row.iter().map(|c| format!("\"{}\"", escape(c))))
        }));
        JsonObj::new()
            .str("id", &self.id)
            .str("title", &self.title)
            .raw("headers", &headers)
            .raw("rows", &rows)
            .finish()
    }

    /// Write `<dir>/<id>.json`; creates the directory.
    pub fn save_json(&self, dir: &Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.id));
        std::fs::write(&path, self.json())?;
        Ok(path)
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders() {
        let mut t = Table::new("t1", "demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("### t1"));
        assert!(md.contains("| a"));
        assert!(md.contains("| 1"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("t2", "demo", &["x"]);
        t.row(vec!["a,b".into()]);
        assert!(t.csv().contains("\"a,b\""));
    }

    #[test]
    fn save_csv_writes_file() {
        let dir = std::env::temp_dir().join("uds_table_test");
        let mut t = Table::new("t3", "demo", &["x"]);
        t.row(vec!["7".into()]);
        let path = t.save_csv(&dir).unwrap();
        assert!(std::fs::read_to_string(path).unwrap().contains('7'));
    }

    #[test]
    fn json_escapes_and_roundtrips_shape() {
        let mut t = Table::new("t4", "q\"uote", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let j = t.json();
        assert!(j.contains("\"id\":\"t4\""));
        assert!(j.contains("q\\\"uote"));
        assert!(j.contains("[[\"1\",\"x,y\"]]"));
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500), "500ns");
        assert_eq!(fmt_ns(1_500), "1.5us");
        assert_eq!(fmt_ns(2_500_000), "2.50ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.00s");
    }
}
