//! End-to-end tests for the schedule conformance analyzer: the full
//! builtin roster conforms, non-conforming schedules are refused at
//! both publish surfaces (§4.2 declare, §4.1 lambda) with stable
//! diagnostic codes, the unchecked opt-outs still register, and the
//! `VERIFY` wire verb streams the same verdicts over TCP.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicI64, Ordering};

use uds::analysis::{verify_all, verify_label, verify_targets, VerifyConfig};
use uds::coordinator::declare::{Args, DeclarationBuilder, Registry};
use uds::coordinator::lambda::UdsBuilder;
use uds::schedules::registry::ScheduleRegistry;
use uds::service::serve_on;
use uds::util::ErrorCode;

/// The acceptance bar: every registered builtin target passes the full
/// two-pass conformance check.
#[test]
fn every_builtin_target_conforms() {
    let reg = ScheduleRegistry::with_builtins();
    let cfg = VerifyConfig::quick();
    let targets = verify_targets(&reg);
    assert!(targets.len() >= 15, "{targets:?}");
    let reports = verify_all(&reg, &cfg);
    assert_eq!(reports.len(), targets.len());
    for r in &reports {
        assert!(r.conforms(), "{}: {:?}", r.label, r.diagnostics);
    }
}

/// A declare-style schedule that silently drops the last iteration:
/// `publish` must refuse it with `coverage_gap`, leave the name free,
/// and `publish_unchecked` must still register it — after which the
/// analyzer reports the same verdict by label.
#[test]
fn declare_publish_refuses_broken_schedule() {
    let decl = Registry::new();
    decl.declare(
        DeclarationBuilder::schedule("drop_last")
            .arguments(2) // omp_arg0 = cursor, omp_arg1 = (deliberately off) limit
            .init(|lb, ub, _incr, _chunk, _nthreads, args| {
                args.arg::<AtomicI64>(0).store(lb, Ordering::Relaxed);
                // The bug under test: stops one iteration short.
                args.arg::<AtomicI64>(1).store(ub - 1, Ordering::Relaxed);
            })
            .next(|lower, upper, incr, _tid, _fb, args| {
                let i = args.arg::<AtomicI64>(0).fetch_add(1, Ordering::Relaxed);
                if i >= args.arg::<AtomicI64>(1).load(Ordering::Relaxed) {
                    return false;
                }
                *lower = i;
                *upper = i + 1;
                *incr = 1;
                true
            })
            .build(),
    )
    .unwrap();
    let make_args = || Args::new().with(AtomicI64::new(0)).with(AtomicI64::new(0));

    let schedules = ScheduleRegistry::new();
    let err = decl
        .publish(&schedules, "drop_last", "drops the last iteration", make_args)
        .unwrap_err();
    assert!(err.contains("coverage_gap"), "{err}");
    assert!(err.contains("drop_last"), "{err}");
    // The refused name stays free for a fixed implementation.
    assert!(!schedules.contains("drop_last"));

    // The opt-out registers it anyway ...
    decl.publish_unchecked(&schedules, "drop_last", "drops the last iteration", make_args)
        .unwrap();
    assert!(schedules.contains("drop_last"));
    // ... and `uds verify` then reports exactly what the gate saw.
    let report = verify_label(&schedules, "drop_last", &VerifyConfig::quick()).unwrap();
    assert!(!report.conforms());
    assert_eq!(report.first_code(), Some(ErrorCode::CoverageGap));
}

/// A lambda-style template that dispatches iteration 0 twice:
/// `register` must refuse it with `coverage_overlap`; the unchecked
/// path still registers, and the analyzer agrees by label.
#[test]
fn lambda_register_refuses_broken_template() {
    let broken = || {
        UdsBuilder::named("bad_overlap")
            .init(|_| Box::new(AtomicI64::new(0)))
            .dequeue(|_ctx, state, _tid, _fb, sink| {
                let cur = state.downcast_ref::<AtomicI64>().unwrap();
                if cur.fetch_add(1, Ordering::Relaxed) < 2 {
                    // The bug under test: the same iteration, twice.
                    sink.chunk_start(0);
                    sink.chunk_end(1);
                } else {
                    sink.dequeue_done();
                }
            })
    };
    let schedules = ScheduleRegistry::new();
    let err = broken().register(&schedules).unwrap_err();
    assert!(err.contains("coverage_overlap"), "{err}");
    assert!(!schedules.contains("bad_overlap"));

    broken().register_unchecked(&schedules).unwrap();
    let report = verify_label(&schedules, "bad_overlap", &VerifyConfig::quick()).unwrap();
    assert_eq!(report.first_code(), Some(ErrorCode::CoverageOverlap));
}

/// The positive publish path: a conforming serial template passes the
/// gate and the by-label analyzer alike.
#[test]
fn lambda_register_accepts_conforming_template() {
    let schedules = ScheduleRegistry::new();
    UdsBuilder::named("ok_serial")
        .init(|_| Box::new(AtomicI64::new(0)))
        .dequeue(|ctx, state, _tid, _fb, sink| {
            let cur = state.downcast_ref::<AtomicI64>().unwrap();
            let k = cur.fetch_add(1, Ordering::Relaxed);
            let lb = ctx.loop_start() + k * ctx.loop_step();
            if lb >= ctx.loop_end() {
                sink.dequeue_done();
                return;
            }
            sink.chunk_start(lb);
            sink.chunk_end(lb + ctx.loop_step());
        })
        .register(&schedules)
        .unwrap();
    assert!(schedules.contains("ok_serial"));
    let report = verify_label(&schedules, "ok_serial", &VerifyConfig::quick()).unwrap();
    assert!(report.conforms(), "{:?}", report.diagnostics);
}

/// The `VERIFY` wire verb over a real TCP round-trip: per-label rows,
/// the terminal summary, stable `ERR` lines for unknown labels, and a
/// full `--all` sweep of the (builtin) global registry.
#[test]
fn verify_wire_verb_end_to_end() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || serve_on(listener, 2));

    let mut c = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());

    // One conforming label: a verify row, then the summary.
    writeln!(c, "VERIFY guided").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"type\":\"verify\""), "{line}");
    assert!(line.contains("\"conforms\":true"), "{line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"type\":\"verify_summary\""), "{line}");
    assert!(line.contains("\"conforming\":1"), "{line}");

    // Unknown labels answer the stable code; the connection survives.
    line.clear();
    writeln!(c, "VERIFY no_such_schedule_xyz").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR bad_schedule"), "{line}");

    // --all sweeps every registered target of the server's registry.
    writeln!(c, "VERIFY --all").unwrap();
    let mut rows = 0usize;
    loop {
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        if l.contains("\"type\":\"verify_summary\"") {
            // This test binary never registers broken schedules into
            // the global registry, so the sweep is all-conforming.
            let labels = flat_u64(&l, "labels");
            assert!(labels >= 20, "{l}");
            assert_eq!(flat_u64(&l, "conforming"), labels, "{l}");
            break;
        }
        assert!(l.contains("\"type\":\"verify\""), "{l}");
        rows += 1;
    }
    assert!(rows >= 20, "{rows}");
}

/// Pull one numeric field out of a flat NDJSON row.
fn flat_u64(line: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let rest = &line[line.find(&pat).unwrap() + pat.len()..];
    rest.chars()
        .take_while(char::is_ascii_digit)
        .collect::<String>()
        .parse()
        .unwrap()
}
