//! Golden-table regression for the E2/E3 evaluation tables.
//!
//! EXPERIMENTS.md claims every simulator experiment is deterministic:
//! identical config + seed ⇒ bit-identical tables.  This harness pins
//! that end to end: it regenerates E2 (makespan matrix) and E3
//! (imbalance/dequeues) on the virtual-time simulator at a fixed
//! [`GOLDEN`] config and asserts **byte identity** against the
//! committed snapshot `tests/goldens/e2_e3.csv`.
//!
//! Lifecycle:
//!
//! * A committed snapshot whose first line starts with `# PROVISIONAL`
//!   is a bootstrap placeholder (authored on a machine without the Rust
//!   toolchain): the test then enforces only the determinism half of
//!   the claim (two independent regenerations, each with its own scoped
//!   thread pool and arenas, must be byte-identical) and prints how to
//!   freeze real bytes.
//! * `UPDATE_GOLDENS=1 cargo test --test golden_tables` rewrites the
//!   snapshot from the current build — the reviewed way to bless an
//!   intentional table change.
//! * Otherwise any byte of drift — row order, float formatting, roster
//!   contents, simulator physics — fails the test.

use std::fmt::Write as _;
use std::path::PathBuf;

use uds::eval::{self, EvalConfig};
use uds::schedules::ScheduleSpec;
use uds::workload::WorkloadClass;

/// The pinned golden config: small enough to regenerate in seconds,
/// large enough that every schedule's chunking behavior is exercised.
const GOLDEN: EvalConfig =
    EvalConfig { n: 20_000, p: 8, mean_ns: 1_000.0, h_ns: 250, seed: 42 };

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens/e2_e3.csv")
}

/// Render E2 + E3 as one canonical CSV document with a config header.
fn render() -> String {
    let mut doc = String::new();
    let _ = writeln!(
        doc,
        "# golden E2/E3 tables — regenerate with \
`UPDATE_GOLDENS=1 cargo test --test golden_tables`"
    );
    let _ = writeln!(
        doc,
        "# config: n={} threads={} mean_ns={} h_ns={} seed={}",
        GOLDEN.n, GOLDEN.p, GOLDEN.mean_ns, GOLDEN.h_ns, GOLDEN.seed
    );
    for table in eval::e2(&GOLDEN).into_iter().chain(eval::e3(&GOLDEN)) {
        let _ = writeln!(doc, "# table: {}", table.id);
        doc.push_str(&table.csv());
    }
    doc
}

#[test]
fn e2_e3_match_committed_goldens() {
    let doc = render();

    // Shape sanity before any byte comparison: every roster schedule
    // appears in every table, one column per workload class.
    let roster_len = ScheduleSpec::roster().len();
    for id in ["e2_makespan", "e2_makespan_abs", "e3_imbalance"] {
        assert!(doc.contains(&format!("# table: {id}")), "missing table {id}");
    }
    let e2_header_cols = 1 + WorkloadClass::ALL.len();
    let first_data_line = doc
        .lines()
        .find(|l| !l.starts_with('#'))
        .expect("table header line");
    assert_eq!(
        first_data_line.split(',').count(),
        e2_header_cols,
        "E2 header shape: {first_data_line}"
    );
    assert!(roster_len >= 18, "roster shrank to {roster_len}");

    // The determinism claim, end to end: an independent regeneration
    // (fresh CostIndex builds, fresh scoped thread pools, fresh arenas)
    // is byte-identical.
    assert_eq!(doc, render(), "E2/E3 regeneration is not deterministic");

    let path = golden_path();
    if std::env::var_os("UPDATE_GOLDENS").is_some() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &doc).unwrap();
        eprintln!("goldens refreshed: {}", path.display());
        return;
    }
    let committed = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{} missing ({e}); commit a snapshot", path.display()));
    if committed.starts_with("# PROVISIONAL") {
        // Bootstrap placeholder: PR-time CI stays green (the determinism
        // half above still ran), but the nightly deep profile sets
        // GOLDEN_STRICT=1 so the unarmed byte-identity gate is a visible
        // failure there, bounding how long the placeholder can linger.
        assert!(
            std::env::var_os("GOLDEN_STRICT").is_none(),
            "goldens are still the PROVISIONAL placeholder — freeze real bytes \
with `UPDATE_GOLDENS=1 cargo test --test golden_tables` and commit {}",
            path.display()
        );
        eprintln!(
            "goldens are a PROVISIONAL placeholder — freeze real bytes with \
`UPDATE_GOLDENS=1 cargo test --test golden_tables` and commit {}",
            path.display()
        );
        return;
    }
    assert_eq!(
        doc, committed,
        "E2/E3 diverged from {}; if the change is intentional, regenerate \
with UPDATE_GOLDENS=1 and commit the diff",
        path.display()
    );
}

/// The pinned E9 golden config: a smaller loop than E2/E3 because every
/// scenario is 10 invocations × (arms + selectors) simulations.
const GOLDEN_E9: EvalConfig =
    EvalConfig { n: 2_000, p: 4, mean_ns: 1_000.0, h_ns: 250, seed: 42 };

fn e9_golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens/e9_regret.csv")
}

fn render_e9() -> String {
    let mut doc = String::new();
    let _ = writeln!(
        doc,
        "# golden E9 regret tables — regenerate with \
`UPDATE_GOLDENS=1 cargo test --test golden_tables`"
    );
    let _ = writeln!(
        doc,
        "# config: n={} threads={} mean_ns={} h_ns={} seed={}",
        GOLDEN_E9.n, GOLDEN_E9.p, GOLDEN_E9.mean_ns, GOLDEN_E9.h_ns, GOLDEN_E9.seed
    );
    for table in eval::e9(&GOLDEN_E9, None) {
        let _ = writeln!(doc, "# table: {}", table.id);
        doc.push_str(&table.csv());
    }
    doc
}

/// Same lifecycle as the E2/E3 golden: determinism is always enforced;
/// byte identity arms once a non-`# PROVISIONAL` snapshot is committed.
#[test]
fn e9_regret_matches_committed_goldens() {
    let doc = render_e9();

    for id in ["e9_regret", "e9_regret_scenarios"] {
        assert!(doc.contains(&format!("# table: {id}")), "missing table {id}");
    }
    for selector in ["auto", "bandit:ucb", "bandit:eps"] {
        assert!(doc.contains(selector), "selector {selector} missing:\n{doc}");
    }
    assert_eq!(doc, render_e9(), "E9 regeneration is not deterministic");

    let path = e9_golden_path();
    if std::env::var_os("UPDATE_GOLDENS").is_some() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &doc).unwrap();
        eprintln!("goldens refreshed: {}", path.display());
        return;
    }
    let committed = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{} missing ({e}); commit a snapshot", path.display()));
    if committed.starts_with("# PROVISIONAL") {
        assert!(
            std::env::var_os("GOLDEN_STRICT").is_none(),
            "E9 goldens are still the PROVISIONAL placeholder — freeze real \
bytes with `UPDATE_GOLDENS=1 cargo test --test golden_tables` and commit {}",
            path.display()
        );
        eprintln!(
            "E9 goldens are a PROVISIONAL placeholder — freeze real bytes with \
`UPDATE_GOLDENS=1 cargo test --test golden_tables` and commit {}",
            path.display()
        );
        return;
    }
    assert_eq!(
        doc, committed,
        "E9 diverged from {}; if the change is intentional, regenerate \
with UPDATE_GOLDENS=1 and commit the diff",
        path.display()
    );
}

/// The golden document embeds its own config header, so a snapshot can
/// never silently be compared against tables from a different config.
#[test]
fn golden_document_carries_its_config() {
    let doc = render();
    assert!(doc.contains("# config: n=20000 threads=8 mean_ns=1000 h_ns=250 seed=42"),
        "config header drifted:\n{}",
        doc.lines().take(3).collect::<Vec<_>>().join("\n"));
}
