//! Property-based tests over the coordinator invariants: exact cover,
//! determinism, UDS-port equivalence, simulator bounds.
//!
//! Offline substitution for `proptest`: a seeded-PRNG case generator
//! (`cases`) runs each property over N random configurations and reports
//! the failing seed, so any failure is reproducible by fixing `BASE_SEED`.
//! The `PROPTEST_CASES` environment variable overrides every property's
//! case count (the nightly CI workflow runs with `PROPTEST_CASES=2048`;
//! PR-time CI stays on the quick per-test defaults).

use uds::coordinator::{drain_chunks, verify_cover, LoopRecord, LoopSpec, ScheduleFactory, TeamSpec};
use uds::schedules::ScheduleSpec;
use uds::sim::{simulate, simulate_indexed, NoVariability, SimArena, SimConfig, VariabilitySpec};
use uds::util::rng::Pcg;
use uds::workload::{CostIndex, CostModel, Dist, SyntheticCost, WorkloadRegistry, WorkloadSpec};

const BASE_SEED: u64 = 0xC0FFEE;

/// Run `prop` over `n_cases` PRNG-derived cases (or `PROPTEST_CASES`
/// when set — the nightly deep profile); panic with the case seed on
/// failure so it can be replayed.
fn cases(name: &str, n_cases: u64, mut prop: impl FnMut(&mut Pcg)) {
    let n_cases = std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(n_cases);
    for case in 0..n_cases {
        let seed = BASE_SEED ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_roster_spec(rng: &mut Pcg) -> ScheduleSpec {
    let roster = ScheduleSpec::roster();
    roster[rng.range_u64(0, roster.len() as u64 - 1) as usize].clone()
}

/// THE invariant: every scheduler covers an arbitrary iteration space
/// exactly once under the canonical drain interleaving.
#[test]
fn prop_exact_cover() {
    cases("exact_cover", 120, |rng| {
        let spec = random_roster_spec(rng);
        let n = rng.range_u64(0, 5_000);
        let p = rng.range_u64(1, 11) as usize;
        let mut s = spec.build();
        let chunks = drain_chunks(
            &mut *s,
            &LoopSpec::upto(n),
            &TeamSpec::uniform(p),
            &mut LoopRecord::default(),
        );
        if n > 0 {
            verify_cover(&chunks, n)
                .unwrap_or_else(|e| panic!("{} n={n} p={p}: {e}", spec.label()));
        } else {
            assert!(chunks.is_empty(), "{}: empty loop produced chunks", spec.label());
        }
    });
}

/// Strided loops: iteration counts and logical mapping hold for
/// arbitrary (lb, len, incr), both directions.
#[test]
fn prop_strided_cover() {
    cases("strided_cover", 80, |rng| {
        let spec = random_roster_spec(rng);
        let lb = rng.range_u64(0, 2_000) as i64 - 1_000;
        let len = rng.range_u64(0, 2_000);
        let mag = rng.range_u64(1, 19) as i64;
        let incr = if rng.f64() < 0.5 { mag } else { -mag };
        let ub = lb + len as i64 * incr;
        let loop_spec = LoopSpec::new(lb, ub, incr).unwrap();
        assert_eq!(loop_spec.iter_count(), len, "geometry setup");
        let p = rng.range_u64(1, 7) as usize;
        let mut s = spec.build();
        let chunks = drain_chunks(
            &mut *s,
            &loop_spec,
            &TeamSpec::uniform(p),
            &mut LoopRecord::default(),
        );
        if len > 0 {
            verify_cover(&chunks, len).unwrap_or_else(|e| {
                panic!("{} lb={lb} incr={incr} len={len}: {e}", spec.label())
            });
        }
    });
}

/// Chunk sequences are deterministic run-to-run (same interleaving).
#[test]
fn prop_deterministic_chunks() {
    cases("deterministic_chunks", 60, |rng| {
        let spec = random_roster_spec(rng);
        let n = rng.range_u64(1, 3_000);
        let p = rng.range_u64(1, 7) as usize;
        let drain = || {
            let mut s = spec.build();
            drain_chunks(
                &mut *s,
                &LoopSpec::upto(n),
                &TeamSpec::uniform(p),
                &mut LoopRecord::default(),
            )
        };
        assert_eq!(drain(), drain(), "{} n={n} p={p}", spec.label());
    });
}

/// Simulator physics: serial/P <= makespan <= serial + dequeue costs.
#[test]
fn prop_sim_makespan_bounds() {
    cases("sim_makespan_bounds", 60, |rng| {
        let spec = random_roster_spec(rng);
        let n = rng.range_u64(1, 2_000);
        let p = rng.range_u64(1, 7) as usize;
        let h = rng.range_u64(0, 500);
        let seed = rng.next_u64();
        let costs = SyntheticCost::new(n, 200.0, Dist::Lognormal { sigma: 0.8 }, seed);
        let serial = costs.total_ns();
        let stats = simulate(
            &LoopSpec::upto(n),
            &TeamSpec::uniform(p),
            &*spec.factory(),
            &costs,
            &NoVariability,
            &mut LoopRecord::default(),
            &SimConfig { dequeue_overhead_ns: h, trace: false },
        );
        let lower = serial / p as u64;
        let upper = serial + stats.total_dequeues() * h + p as u64 * h + p as u64 + 1;
        assert!(
            stats.makespan_ns >= lower,
            "{}: makespan {} < critical path {lower}",
            spec.label(),
            stats.makespan_ns
        );
        assert!(
            stats.makespan_ns <= upper,
            "{}: makespan {} > serial+overhead {upper}",
            spec.label(),
            stats.makespan_ns
        );
        assert_eq!(stats.iters.iter().sum::<u64>(), n, "{}", spec.label());
    });
}

/// GSS's closed-form sequence: sums to n, nonincreasing, head ceil(n/p).
#[test]
fn prop_gss_sequence_closed_form() {
    cases("gss_sequence", 200, |rng| {
        let n = rng.range_u64(1, 50_000);
        let p = rng.range_u64(1, 31);
        let seq = uds::schedules::Gss::sequence(n, p, 1);
        assert_eq!(seq.iter().sum::<u64>(), n);
        assert!(seq.windows(2).all(|w| w[0] >= w[1]));
        assert_eq!(seq[0], n.div_ceil(p));
    });
}

/// TSS and FAC2 compiled sequences always cover exactly.
#[test]
fn prop_compiled_sequences_cover() {
    cases("compiled_sequences", 200, |rng| {
        let n = rng.range_u64(0, 100_000);
        let p = rng.range_u64(1, 31);
        let tss: u64 = uds::schedules::Tss::sequence(n, p, None).iter().sum();
        assert_eq!(tss, n, "tss n={n} p={p}");
        let fac2: u64 = uds::schedules::Fac2::sequence(n, p).iter().sum();
        assert_eq!(fac2, n, "fac2 n={n} p={p}");
    });
}

/// UDS lambda ports are chunk-identical to natives for arbitrary geometry
/// (the E6 property, generalized).
#[test]
fn prop_lambda_ports_equiv() {
    cases("lambda_ports_equiv", 40, |rng| {
        use uds::schedules::uds_port;
        let n = rng.range_u64(1, 3_000);
        let p = rng.range_u64(1, 7) as usize;
        let k = rng.range_u64(1, 63);
        let team = TeamSpec::uniform(p);
        let spec = LoopSpec::upto(n);
        let pairs: Vec<(
            Box<dyn uds::coordinator::Scheduler>,
            Box<dyn uds::coordinator::Scheduler>,
            &str,
        )> = vec![
            (
                uds::schedules::static_block(Some(k)),
                uds_port::lambda_static(k).build(),
                "static",
            ),
            (
                uds::schedules::dynamic_chunk(k),
                uds_port::lambda_dynamic(k).build(),
                "dynamic",
            ),
            (uds::schedules::gss(1), uds_port::lambda_gss(1).build(), "gss"),
            (uds::schedules::tss(None), uds_port::lambda_tss().build(), "tss"),
            (uds::schedules::fac2(), uds_port::lambda_fac2().build(), "fac2"),
        ];
        for (mut native, mut uds_s, name) in pairs {
            let a = drain_chunks(&mut *native, &spec, &team, &mut LoopRecord::default());
            let b = drain_chunks(&mut *uds_s, &spec, &team, &mut LoopRecord::default());
            assert_eq!(a, b, "{name} n={n} p={p} k={k}");
        }
    });
}

/// The prefix-sum cost index: `range_ns(lo, hi)` equals direct
/// `cost_ns` summation for arbitrary ranges, across every `Dist`
/// variant, and the derived totals/stats agree with the model's.
#[test]
fn prop_cost_index_matches_direct_sum() {
    let dists = [
        Dist::Constant,
        Dist::Linear { rising: true },
        Dist::Linear { rising: false },
        Dist::Gaussian { cv: 0.3 },
        Dist::Exponential,
        Dist::Lognormal { sigma: 1.0 },
        Dist::Bimodal { frac_heavy: 0.1, ratio: 10.0 },
        Dist::Sawtooth { period: 17 },
    ];
    cases("cost_index_range", 25, |rng| {
        for dist in dists {
            let n = rng.range_u64(1, 2_000);
            let seed = rng.next_u64();
            let mean = 10.0 + rng.f64() * 2_000.0;
            let model = SyntheticCost::new(n, mean, dist, seed);
            let index = CostIndex::build(&model);
            assert_eq!(index.len(), n);
            assert_eq!(index.total_ns(), model.total_ns(), "{dist:?}");
            for _ in 0..8 {
                let lo = rng.range_u64(0, n);
                let hi = rng.range_u64(lo, n);
                let direct: u64 = (lo..hi).map(|i| model.cost_ns(i)).sum();
                assert_eq!(
                    index.range_ns(lo, hi),
                    direct,
                    "{dist:?} n={n} [{lo},{hi})"
                );
            }
            let i = rng.range_u64(0, n - 1);
            assert_eq!(index.cost_ns(i), model.cost_ns(i), "{dist:?} i={i}");
        }
    });
}

/// The indexed hot path (shared CostIndex + reused SimArena) is
/// bit-identical to the one-shot `simulate` wrapper for arbitrary
/// schedule/geometry/overhead, including back-to-back arena reuse.
#[test]
fn prop_indexed_sim_equals_wrapper() {
    cases("indexed_sim_equivalence", 40, |rng| {
        let spec = random_roster_spec(rng);
        let n = rng.range_u64(1, 2_000);
        let p = rng.range_u64(1, 9) as usize;
        let h = rng.range_u64(0, 400);
        let seed = rng.next_u64();
        let costs = SyntheticCost::new(n, 300.0, Dist::Exponential, seed);
        let cfg = SimConfig { dequeue_overhead_ns: h, trace: false };
        let reference = simulate(
            &LoopSpec::upto(n),
            &TeamSpec::uniform(p),
            &*spec.factory(),
            &costs,
            &NoVariability,
            &mut LoopRecord::default(),
            &cfg,
        );
        let index = CostIndex::build(&costs);
        let mut arena = SimArena::new();
        for round in 0..2 {
            let fast = simulate_indexed(
                &LoopSpec::upto(n),
                &TeamSpec::uniform(p),
                &*spec.factory(),
                &index,
                &NoVariability,
                &mut LoopRecord::default(),
                &cfg,
                &mut arena,
            );
            assert_eq!(
                fast.makespan_ns, reference.makespan_ns,
                "{} n={n} p={p} h={h} round={round}",
                spec.label()
            );
            assert_eq!(fast.iters, reference.iters, "{}", spec.label());
            assert_eq!(fast.busy_ns, reference.busy_ns, "{}", spec.label());
            assert_eq!(fast.dequeues, reference.dequeues, "{}", spec.label());
            assert_eq!(fast.chunks, reference.chunks, "{}", spec.label());
        }
    });
}

/// The batched SoA kernel is bit-identical to the scalar path: for
/// random (workload label, roster schedule, threads, variability,
/// K ≤ 32, seed block), every `simulate_batch` lane result is
/// field-for-field equal to a scalar `simulate_indexed` call with the
/// same inputs — whether the lanes share one `CostIndex` (the
/// cached-index sweep case) or carry per-seed indexes.
#[test]
fn prop_batch_matches_scalar() {
    use uds::sim::{simulate_batch, BatchArena, BatchLane};

    let workloads = [
        "uniform",
        "increasing",
        "decreasing",
        "gaussian",
        "exponential",
        "lognormal",
        "bimodal",
        "sawtooth",
        "mix:gaussian:lognormal",
        "phased:increasing:uniform,0.5",
        "burst:uniform",
        "trace:stairs",
    ];
    cases("batch_matches_scalar", 14, |rng| {
        let spec = random_roster_spec(rng);
        let wl = workloads[rng.range_u64(0, workloads.len() as u64 - 1) as usize];
        let wspec = WorkloadSpec::parse(wl).unwrap();
        let n = rng.range_u64(1, 1_200);
        let p = rng.range_u64(1, 9) as usize;
        let h = rng.range_u64(0, 400);
        let k = rng.range_u64(1, 32);
        let vspec = match rng.range_u64(0, 2) {
            0 => VariabilitySpec::Calm,
            1 => VariabilitySpec::parse("hetero:1,2,0.5").unwrap(),
            _ => VariabilitySpec::parse(&format!("noise:0.2,0.5,{}", rng.next_u64()))
                .unwrap(),
        };
        let var = vspec.build(p);
        let base_seed = rng.next_u64();
        // Half the cases share one index across every lane (the
        // cached-index sweep case); half seed each lane independently.
        let shared = rng.f64() < 0.5;
        let mean = 100.0 + rng.f64() * 900.0;
        let indexes: Vec<CostIndex> = (0..k)
            .map(|l| {
                let seed =
                    if shared { base_seed } else { base_seed.wrapping_add(l) };
                CostIndex::build(&*wspec.model(n, mean, seed))
            })
            .collect();
        let lanes: Vec<BatchLane> = indexes
            .iter()
            .map(|index| BatchLane { index, var: &*var })
            .collect();
        let mut records: Vec<LoopRecord> =
            (0..k).map(|_| LoopRecord::default()).collect();
        let cfg = SimConfig { dequeue_overhead_ns: h, trace: false };
        let got = simulate_batch(
            &LoopSpec::upto(n),
            &TeamSpec::uniform(p),
            &*spec.factory(),
            &lanes,
            &mut records,
            &cfg,
            &mut BatchArena::new(),
        );
        let mut arena = SimArena::new();
        for (l, index) in indexes.iter().enumerate() {
            let want = simulate_indexed(
                &LoopSpec::upto(n),
                &TeamSpec::uniform(p),
                &*spec.factory(),
                index,
                &*var,
                &mut LoopRecord::default(),
                &cfg,
                &mut arena,
            );
            let ctx = format!(
                "{} wl={wl} vl={} n={n} p={p} h={h} k={k} shared={shared} lane {l}",
                spec.label(),
                vspec.label()
            );
            assert_eq!(got[l].makespan_ns, want.makespan_ns, "{ctx}: makespan");
            assert_eq!(got[l].busy_ns, want.busy_ns, "{ctx}: busy");
            assert_eq!(got[l].finish_ns, want.finish_ns, "{ctx}: finish");
            assert_eq!(got[l].iters, want.iters, "{ctx}: iters");
            assert_eq!(got[l].dequeues, want.dequeues, "{ctx}: dequeues");
            assert_eq!(got[l].chunks, want.chunks, "{ctx}: chunks");
        }
    });
}

/// Workload generators: requested mean is hit within tolerance.
#[test]
fn prop_workload_means() {
    cases("workload_means", 10, |rng| {
        use uds::workload::WorkloadClass;
        let seed = rng.next_u64();
        let mean = 50.0 + rng.f64() * 4_950.0;
        for class in WorkloadClass::ALL {
            let m = class.model(20_000, mean, seed);
            let (got, _sd) = m.stats();
            assert!(
                (got - mean).abs() / mean < 0.25,
                "{}: mean {got} want {mean}",
                class.name()
            );
        }
    });
}

/// Metrics: imbalance is scale-invariant and nonnegative.
#[test]
fn prop_imbalance_properties() {
    cases("imbalance_properties", 100, |rng| {
        let len = rng.range_u64(1, 31) as usize;
        let xs: Vec<u64> = (0..len).map(|_| rng.range_u64(1, 1_000_000)).collect();
        let imb = uds::metrics::ratio_imbalance(&xs);
        assert!(imb >= 0.0);
        let scaled: Vec<u64> = xs.iter().map(|&x| x * 3).collect();
        let imb2 = uds::metrics::ratio_imbalance(&scaled);
        assert!((imb - imb2).abs() < 1e-9);
    });
}

/// Sweep sharding is physics-invariant: for arbitrary small grids, the
/// per-scenario result stream is bit-identical whether 1 worker or N
/// workers executed it (the ISSUE-3 batch determinism pin).
#[test]
fn prop_sweep_worker_count_invariant() {
    use uds::eval::report::ScenarioResult;
    use uds::service::Service;
    use uds::sweep::{run_sweep, SweepGrid};
    cases("sweep_worker_invariance", 8, |rng| {
        let workloads = ["uniform", "gaussian", "lognormal", "bimodal"];
        let scheds = ["fac2", "gss", "static", "dynamic,16", "tss", "awf-b"];
        let pick = |rng: &mut Pcg, pool: &[&str]| {
            pool[rng.range_u64(0, pool.len() as u64 - 1) as usize].to_string()
        };
        let line = format!(
            "BATCH workloads={},{} schedules={};{} n={},{} threads={},{} seeds={}",
            pick(rng, &workloads),
            pick(rng, &workloads),
            pick(rng, &scheds),
            pick(rng, &scheds),
            rng.range_u64(50, 1_500),
            rng.range_u64(50, 1_500),
            rng.range_u64(1, 6),
            rng.range_u64(1, 6),
            rng.range_u64(0, 999),
        );
        let grid = SweepGrid::parse_batch_line(&line).unwrap();
        let scenarios = grid.expand();
        let workers = rng.range_u64(2, 8) as usize;
        let (a, _) = run_sweep(&Service::new(), &scenarios, 1);
        let (b, _) = run_sweep(&Service::new(), &scenarios, workers);
        let wire = |rs: &[ScenarioResult]| {
            rs.iter().map(|r| r.json_line()).collect::<Vec<_>>()
        };
        assert_eq!(wire(&a), wire(&b), "workers={workers} grid={line}");
    });
}

/// Selector heads keep the sweep engine's bit-identity guarantee: all
/// bandit/expert state lives in the per-scenario `LoopRecord`, never in
/// the factory or any global, so a grid of selector scenarios produces
/// the same wire rows no matter how many workers race over it.
#[test]
fn prop_bandit_sweep_worker_invariance() {
    use uds::eval::report::ScenarioResult;
    use uds::service::Service;
    use uds::sweep::{run_sweep, SweepGrid};
    cases("bandit_sweep_worker_invariance", 6, |rng| {
        let workloads = [
            "phased:uniform:gaussian",
            "phased:increasing:uniform",
            "burst:uniform",
            "burst:lognormal",
            "gaussian",
        ];
        let scheds = [
            "bandit:ucb",
            "bandit:ucb,2.5",
            "bandit:eps",
            "bandit:eps,0.3",
            "auto",
        ];
        let pick = |rng: &mut Pcg, pool: &[&str]| {
            pool[rng.range_u64(0, pool.len() as u64 - 1) as usize].to_string()
        };
        let line = format!(
            "BATCH workloads={};{} schedules={};{} n={},{} threads={},{} seeds={}",
            pick(rng, &workloads),
            pick(rng, &workloads),
            pick(rng, &scheds),
            pick(rng, &scheds),
            rng.range_u64(50, 1_200),
            rng.range_u64(50, 1_200),
            rng.range_u64(1, 6),
            rng.range_u64(1, 6),
            rng.range_u64(0, 999),
        );
        let grid = SweepGrid::parse_batch_line(&line).unwrap();
        let scenarios = grid.expand();
        let workers = rng.range_u64(2, 8) as usize;
        let (a, _) = run_sweep(&Service::new(), &scenarios, 1);
        let (b, _) = run_sweep(&Service::new(), &scenarios, workers);
        let wire = |rs: &[ScenarioResult]| {
            rs.iter().map(|r| r.json_line()).collect::<Vec<_>>()
        };
        assert_eq!(wire(&a), wire(&b), "workers={workers} grid={line}");
    });
}

/// Registry labels roundtrip: for every registered head — builtin
/// canonical names, their aliases, and freshly registered user-defined
/// names — the bare head and randomly parameterized labels all parse to
/// specs whose canonical label is a fixed point (`parse(label()) ==
/// spec` and `parse(label()).label() == label()`).
#[test]
fn prop_registry_label_roundtrip() {
    use std::sync::Arc;
    use uds::coordinator::FnFactory;
    use uds::schedules::registry::{ParamKind, ScheduleRegistry};

    let reg = ScheduleRegistry::global();
    // Seed user-defined names into the shared namespace (idempotent:
    // the global registry persists across tests in this binary).
    for name in ["prop-uds-a", "prop-uds-b"] {
        let _ = reg.register_factory(
            name,
            Arc::new(FnFactory::new(name, || uds::schedules::fac2())),
            "proptest uds",
        );
    }

    fn roundtrip(label: &str) {
        let spec =
            ScheduleSpec::parse(label).unwrap_or_else(|e| panic!("'{label}': {e}"));
        let canon = spec.label();
        let back = ScheduleSpec::parse(&canon)
            .unwrap_or_else(|e| panic!("canonical '{canon}' of '{label}': {e}"));
        assert_eq!(back, spec, "label '{label}' canonical '{canon}'");
        assert_eq!(back.label(), canon, "'{canon}' must be a parse→label fixed point");
    }

    cases("registry_label_roundtrip", 40, |rng| {
        for entry in reg.entries() {
            // Bare heads: canonical name and every alias.
            roundtrip(entry.name());
            for alias in entry.aliases() {
                roundtrip(alias);
            }
            if entry.params().is_empty() {
                continue;
            }
            // Fully parameterized label with random values.  u64 values
            // are generated nondecreasing so constrained pairs (rand's
            // 1 <= lo <= hi) stay valid; f64 values are finite positives.
            let mut vals: Vec<String> = Vec::new();
            let mut last_u = rng.range_u64(1, 8);
            for p in entry.params() {
                match p.kind {
                    ParamKind::U64 => {
                        last_u += rng.range_u64(0, 8);
                        vals.push(last_u.to_string());
                    }
                    ParamKind::F64 => {
                        let v = 0.5 + rng.f64() * 1000.0;
                        vals.push(format!("{v}"));
                    }
                }
            }
            roundtrip(&format!("{},{}", entry.name(), vals.join(",")));
        }
    });

    // Roster labels are canonical and lossless.
    for spec in ScheduleSpec::roster() {
        let label = spec.label();
        assert_eq!(ScheduleSpec::parse(&label).unwrap(), spec, "{label}");
    }
}

/// The ISSUE-5 workload-registry property: for every registered head —
/// the 8 builtin classes, the composite heads (`mix`/`phased`/`burst`/
/// `trace`) and freshly registered user heads — randomly parameterized
/// labels (1) roundtrip `parse → label → parse` to an equal spec with a
/// canonical fixed point, and (2) build models whose prefix-sum
/// `CostIndex::range_ns` equals direct per-iteration `cost_ns`
/// summation, with pure `(seed, i)` random access.
#[test]
fn prop_workload_registry_roundtrip_and_prefix_sums() {
    use uds::workload::registry::{registration, ParamKind, SubKind};
    use uds::workload::TraceCost;

    let reg = WorkloadRegistry::global();
    // Seed a user-defined trace and head into the shared namespace
    // (idempotent: the global registry persists across tests).
    let _ = reg.register_trace("prop-trace", vec![100, 900, 100, 250]);
    let _ = reg.register(
        registration("prop-steps")
            .param("levels", ParamKind::U64, "4")
            .summary("proptest user head: step function")
            .build(|ctx| {
                let levels = ctx.u64_param(0, 4).max(1);
                let n = ctx.n;
                let costs: Vec<u64> = (0..n)
                    .map(|i| 100 * (1 + (i * levels / n.max(1)).min(levels - 1)))
                    .collect();
                Ok(Box::new(TraceCost::new(costs)))
            }),
    );

    const SIMPLE: [&str; 8] = [
        "uniform",
        "increasing",
        "decreasing",
        "gaussian",
        "exponential",
        "lognormal",
        "bimodal",
        "sawtooth",
    ];

    fn roundtrip(label: &str) -> WorkloadSpec {
        let spec =
            WorkloadSpec::parse(label).unwrap_or_else(|e| panic!("'{label}': {e}"));
        let canon = spec.label().to_string();
        let back = WorkloadSpec::parse(&canon)
            .unwrap_or_else(|e| panic!("canonical '{canon}' of '{label}': {e}"));
        assert_eq!(back, spec, "label '{label}' canonical '{canon}'");
        assert_eq!(back.label(), canon, "'{canon}' must be a parse→label fixed point");
        spec
    }

    fn check_prefix_sums(spec: &WorkloadSpec, rng: &mut Pcg) {
        let n = rng.range_u64(1, 1_200);
        let mean = 50.0 + rng.f64() * 2_000.0;
        let seed = rng.next_u64();
        let model = spec.model(n, mean, seed);
        assert_eq!(model.len(), n, "{}", spec.label());
        let index = CostIndex::build(&*model);
        assert_eq!(index.len(), n);
        assert_eq!(index.total_ns(), model.total_ns(), "{}", spec.label());
        for _ in 0..6 {
            let lo = rng.range_u64(0, n);
            let hi = rng.range_u64(lo, n);
            let direct: u64 = (lo..hi).map(|i| model.cost_ns(i)).sum();
            assert_eq!(
                index.range_ns(lo, hi),
                direct,
                "{} n={n} [{lo},{hi})",
                spec.label()
            );
        }
        // Pure (seed, i): out-of-order access and an independently built
        // model agree with the sequential enumeration.
        let twin = spec.model(n, mean, seed);
        for _ in 0..4 {
            let i = rng.range_u64(0, n - 1);
            assert_eq!(model.cost_ns(i), twin.cost_ns(i), "{} i={i}", spec.label());
            assert_eq!(index.cost_ns(i), model.cost_ns(i), "{} i={i}", spec.label());
        }
    }

    // Random valid parameterized labels per head; heads introduced later
    // must extend this table (the coverage assertion below enforces it).
    fn param_labels(head: &str, rng: &mut Pcg) -> Vec<String> {
        let pick = |rng: &mut Pcg| SIMPLE[rng.range_u64(0, 7) as usize];
        let mean = 100 + rng.range_u64(0, 5_000);
        match head {
            "uniform" | "increasing" | "decreasing" | "exponential" => {
                vec![format!("{head},mean={mean}")]
            }
            "gaussian" => {
                vec![format!("gaussian,mean={mean},cv={}", 0.05 + rng.f64() * 0.6)]
            }
            "lognormal" => {
                vec![format!("lognormal,sigma={}", 0.2 + rng.f64() * 1.5)]
            }
            "bimodal" => vec![format!(
                "bimodal,frac={},ratio={}",
                rng.f64() * 0.5,
                2.0 + rng.f64() * 20.0
            )],
            "sawtooth" => vec![format!("sawtooth,period={}", 2 + rng.range_u64(0, 200))],
            "mix" => vec![format!(
                "mix:{}:{},frac={}",
                pick(rng),
                pick(rng),
                rng.f64()
            )],
            // Positional form: canonicalizes to switch=<v>.
            "phased" => vec![format!(
                "phased:{}:{},{}",
                pick(rng),
                pick(rng),
                rng.f64()
            )],
            "burst" => vec![format!(
                "burst:{},period={},amp={}",
                pick(rng),
                1 + rng.range_u64(0, 300),
                1.0 + rng.f64() * 15.0
            )],
            "trace" => vec![
                "trace:stairs".into(),
                "trace:spike".into(),
                "trace:prop-trace".into(),
            ],
            "prop-steps" => {
                vec![format!("prop-steps,levels={}", 1 + rng.range_u64(0, 6))]
            }
            _ => Vec::new(),
        }
    }

    cases("workload_registry_roundtrip", 12, |rng| {
        for entry in reg.entries() {
            // Every head: a generically constructed base label...
            let mut base = entry.name().to_string();
            for sub in entry.subs() {
                base.push(':');
                match sub.kind {
                    SubKind::Workload => base.push_str(SIMPLE[rng.range_u64(0, 7) as usize]),
                    SubKind::Token => base.push_str("stairs"),
                }
            }
            let spec = roundtrip(&base);
            check_prefix_sums(&spec, rng);
            for alias in entry.aliases() {
                roundtrip(alias);
            }
            // ...plus head-specific randomly parameterized labels.
            for label in param_labels(entry.name(), rng) {
                let spec = roundtrip(&label);
                check_prefix_sums(&spec, rng);
            }
        }
    });

    // Coverage pin: the parameter-template table above must know every
    // *shipped* head (user heads registered by other tests are covered
    // by their generic base label only).
    for head in SIMPLE
        .iter()
        .copied()
        .chain(["mix", "phased", "burst", "trace", "prop-steps"])
    {
        assert!(
            reg.contains(head),
            "head '{head}' expected in the global workload registry"
        );
    }
}

/// Variability specs: random atoms and products roundtrip
/// `parse → label → parse` to equal specs, and built models are
/// deterministic functions of `(tid, t)`.
#[test]
fn prop_variability_spec_roundtrip() {
    fn random_atom(rng: &mut Pcg) -> VariabilitySpec {
        match rng.range_u64(0, 2) {
            0 => VariabilitySpec::Calm,
            1 => VariabilitySpec::Hetero {
                speeds: (0..1 + rng.range_u64(0, 5))
                    .map(|_| 0.25 + rng.f64() * 4.0)
                    .collect(),
            },
            _ => VariabilitySpec::Noise {
                prob: rng.f64(),
                slow: 0.05 + rng.f64() * 0.9,
                seed: rng.next_u64(),
                window_ns: 1 + rng.range_u64(0, 1_000_000),
            },
        }
    }
    cases("variability_spec_roundtrip", 60, |rng| {
        let spec = if rng.f64() < 0.3 {
            VariabilitySpec::Product {
                parts: (0..2 + rng.range_u64(0, 2)).map(|_| random_atom(rng)).collect(),
            }
        } else {
            random_atom(rng)
        };
        let label = spec.label();
        let back = VariabilitySpec::parse(&label)
            .unwrap_or_else(|e| panic!("'{label}': {e}"));
        assert_eq!(back, spec, "label '{label}'");
        assert_eq!(back.label(), label, "'{label}' must be a fixed point");
        // Built models are deterministic and positive.
        let threads = 1 + rng.range_u64(0, 7) as usize;
        let a = spec.build(threads);
        let b = spec.build(threads);
        for tid in 0..threads {
            for t in [0u64, 1_000, 123_456] {
                let s = a.speed(tid, t);
                assert!(s > 0.0, "{label} tid={tid} t={t}: speed {s}");
                assert_eq!(s, b.speed(tid, t), "{label} tid={tid} t={t}");
            }
        }
    });
}

/// History-carrying schedules (AWF/AF/auto/tuned) still exact-cover on
/// every invocation of a multi-invocation sequence.
#[test]
fn prop_adaptives_cover_across_invocations() {
    cases("adaptives_multi_invocation", 30, |rng| {
        let n = rng.range_u64(1, 2_000);
        let p = rng.range_u64(1, 7) as usize;
        for label in ["awf-b", "awf-c", "af", "auto", "tuned,4"] {
            let spec = ScheduleSpec::parse(label).unwrap();
            let mut rec = LoopRecord::default();
            for inv in 0..3 {
                let mut s = spec.build();
                let chunks = drain_chunks(
                    &mut *s,
                    &LoopSpec::upto(n),
                    &TeamSpec::uniform(p),
                    &mut rec,
                );
                verify_cover(&chunks, n).unwrap_or_else(|e| {
                    panic!("{label} inv={inv} n={n} p={p}: {e}")
                });
                rec.invocations += 1;
            }
        }
    });
}

/// The conformance analyzer's verdict is workload-independent: every
/// registered builtin target still passes the full pass-2 model check
/// when the feedback timings come from a randomly chosen workload head
/// instead of unit costs (adaptive schedules see realistic chunk
/// timings and must stay violation-free).
#[test]
fn prop_roster_conforms() {
    use uds::analysis::{verify_label_costed, verify_targets, VerifyConfig};
    use uds::schedules::registry::ScheduleRegistry;
    let reg = ScheduleRegistry::with_builtins();
    let targets = verify_targets(&reg);
    assert!(targets.len() >= 15, "{targets:?}");
    let heads = [
        "uniform", "increasing", "decreasing", "gaussian", "exponential",
        "lognormal", "bimodal", "sawtooth", "mix:uniform:lognormal",
        "phased:uniform:exponential", "burst:uniform", "trace:stairs",
    ];
    let cfg = VerifyConfig::quick();
    cases("roster_conforms", 40, |rng| {
        let label = &targets[rng.range_u64(0, targets.len() as u64 - 1) as usize];
        let head = heads[rng.range_u64(0, heads.len() as u64 - 1) as usize];
        let seed = rng.range_u64(0, 1_000_000);
        let wspec = WorkloadRegistry::global()
            .parse(head)
            .unwrap_or_else(|e| panic!("{head}: {e}"));
        let cost = move |n: u64| wspec.model(n, 1000.0, seed);
        let report = verify_label_costed(&reg, label, &cfg, Some(&cost))
            .unwrap_or_else(|e| panic!("{label}: {e}"));
        assert!(
            report.conforms(),
            "{label} x {head} seed={seed}: {:?}",
            report.diagnostics
        );
    });
}
