//! End-to-end tests for the persistent result store: cold→warm sweep
//! byte-identity, append/reopen durability over registry labels, the
//! `QUERY` wire verb (happy path and every store-layer error code),
//! corrupt-segment handling, and the `uds sweep --store` / `uds query`
//! CLI round trip.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use uds::eval::report::ScenarioResult;
use uds::service::{serve_on_with, Service};
use uds::store::{ResultStore, ScenarioKey, StoreSummary};
use uds::sweep::{run_sweep, run_sweep_stored, SweepGrid};
use uds::util::json::parse_flat;
use uds::util::rng::Pcg;

/// Unique scratch directory per call (pid + counter), pre-cleaned.
fn tmp_dir(name: &str) -> PathBuf {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let k = COUNTER.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!(
        "uds_store_e2e_{}_{k}_{name}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

const GRID: &str = "BATCH schedules=fac2;gss;dynamic,16 n=400,900 \
workloads=uniform;gaussian variability=calm;hetero:1,2 threads=4 seeds=1,2 \
workers=3";

/// The tentpole contract: a warm sweep answers entirely from the store
/// — zero index builds, zero simulations — and its result stream is
/// byte-identical to the cold run that populated it.
#[test]
fn warm_sweep_is_byte_identical_with_zero_simulations() {
    let dir = tmp_dir("warm_identity");
    let grid = SweepGrid::parse_batch_line(GRID).unwrap();
    let scenarios = grid.expand();
    let total = scenarios.len() as u64;
    assert_eq!(total, 48, "grid arithmetic drifted");

    let store = ResultStore::open(&dir).unwrap();
    let svc = Service::new();
    let (cold, cold_summary, cold_ss) =
        run_sweep_stored(&svc, &scenarios, grid.workers, &store).unwrap();
    assert_eq!(cold_ss, StoreSummary { hits: 0, misses: total, appended: total });
    assert!(cold_summary.index_builds > 0, "cold run must simulate");
    assert_eq!(store.len() as u64, total);

    // Fresh service + store reopened from disk: nothing warm but the
    // segment files.
    let store2 = ResultStore::open(&dir).unwrap();
    let svc2 = Service::new();
    let (warm, warm_summary, warm_ss) =
        run_sweep_stored(&svc2, &scenarios, grid.workers, &store2).unwrap();
    assert_eq!(warm_ss, StoreSummary { hits: total, misses: 0, appended: 0 });
    assert_eq!(warm_summary.index_builds, 0, "warm run must not build indexes");
    assert_eq!(warm_summary.cache_hits, 0);
    assert_eq!(warm_summary.scenarios, total);
    assert_eq!(svc2.cache_stats(), (0, 0), "warm run must not touch the service");
    assert_eq!(warm_summary.distinct_workloads, cold_summary.distinct_workloads);

    assert_eq!(cold.len(), warm.len());
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!(c.json_line(), w.json_line());
        assert_eq!(c.csv_row(), w.csv_row());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A partially-warm sweep (grid extended with a new seed) simulates
/// only the misses, appends exactly them, and the merged stream is
/// byte-identical to a cold sweep of the extended grid.
#[test]
fn partial_overlap_extends_store_and_merges_in_order() {
    let dir = tmp_dir("partial_overlap");
    let base = SweepGrid::parse_batch_line(GRID).unwrap();
    let extended = SweepGrid::parse_batch_line(&GRID.replace("seeds=1,2", "seeds=1,2,3"))
        .unwrap();
    let store = ResultStore::open(&dir).unwrap();

    let svc = Service::new();
    let base_scenarios = base.expand();
    run_sweep_stored(&svc, &base_scenarios, base.workers, &store).unwrap();

    let scenarios = extended.expand();
    let svc2 = Service::new();
    let (merged, _, ss) =
        run_sweep_stored(&svc2, &scenarios, extended.workers, &store).unwrap();
    assert_eq!(ss, StoreSummary { hits: 48, misses: 24, appended: 24 });
    assert_eq!(store.len(), 72);

    // Reference: the same extended grid cold, no store anywhere.
    let svc_ref = Service::new();
    let (reference, _) = run_sweep(&svc_ref, &scenarios, extended.workers);
    assert_eq!(merged.len(), reference.len());
    for (m, r) in merged.iter().zip(&reference) {
        assert_eq!(m.json_line(), r.json_line(), "merge order or content drifted");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Property: rows with labels drawn from the live registries (and
/// adversarial float fields) survive append → reopen → get bitwise.
#[test]
fn prop_append_reopen_roundtrips_registry_labels() {
    const BASE_SEED: u64 = 0xC0FFEE;
    let schedules: Vec<String> = uds::schedules::ScheduleSpec::roster()
        .iter()
        .map(|s| s.label())
        .collect();
    let workloads = [
        "uniform",
        "gaussian,cv=0.3",
        "lognormal",
        "mix:gaussian:uniform,0.25",
        "phased:increasing:uniform,0.5",
    ];
    let variability = ["calm", "hetero:1,1,2,4", "noise:0.1,2,7"];
    let n_cases = std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(25);
    for case in 0..n_cases {
        let seed = BASE_SEED ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg::seed_from_u64(seed);
        let dir = tmp_dir("prop_roundtrip");
        let store = ResultStore::open(&dir).unwrap();
        let rows = rng.range_u64(1, 8);
        let batch: Vec<ScenarioResult> = (0..rows)
            .map(|i| ScenarioResult {
                id: i,
                schedule: schedules
                    [rng.range_u64(0, schedules.len() as u64 - 1) as usize]
                    .clone(),
                workload: workloads
                    [rng.range_u64(0, workloads.len() as u64 - 1) as usize]
                    .to_string(),
                variability: variability
                    [rng.range_u64(0, variability.len() as u64 - 1) as usize]
                    .to_string(),
                n: rng.range_u64(1, 1_000_000),
                threads: rng.range_u64(1, 64),
                mean_ns: rng.f64() * 1e9 + 0.125,
                h_ns: rng.range_u64(0, 5_000),
                // Distinct seeds keep keys unique within the batch.
                seed: i,
                makespan_ns: rng.range_u64(0, u64::MAX / 2),
                chunks: rng.range_u64(0, 1 << 20),
                dequeues: rng.range_u64(0, 1 << 20),
                imbalance_pct: rng.f64() * 100.0,
                efficiency: rng.f64(),
            })
            .collect();
        assert_eq!(store.append(&batch).unwrap(), rows, "case {case} seed {seed:#x}");
        let reopened = ResultStore::open(&dir).unwrap();
        assert_eq!(reopened.len() as u64, rows);
        for r in &batch {
            let row = reopened
                .get(&ScenarioKey::of_result(r))
                .unwrap_or_else(|| panic!("case {case} seed {seed:#x}: row lost"));
            assert_eq!(
                row.to_result(r.id).json_line(),
                r.json_line(),
                "case {case} seed {seed:#x}: bytes drifted"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Seed a store-backed service with one small BATCH and return it.
fn seeded_service(dir: &PathBuf) -> Service {
    let store = Arc::new(ResultStore::open(dir).unwrap());
    let svc = Service::new().with_store(store);
    let mut out = Vec::new();
    svc.handle_batch(
        "BATCH schedules=fac2;gss n=300 workloads=uniform threads=2 seeds=1,2 \
workers=2",
        &mut out,
    );
    let text = String::from_utf8(out).unwrap();
    assert!(text.lines().count() == 5, "4 results + summary: {text}");
    svc
}

/// The QUERY verb end-to-end over `handle_query`: every op answers
/// rows plus a terminal query_summary.
#[test]
fn query_verb_happy_path() {
    let dir = tmp_dir("query_happy");
    let svc = seeded_service(&dir);

    let run = |line: &str| -> Vec<String> {
        let mut out = Vec::new();
        svc.handle_query(line, &mut out);
        String::from_utf8(out).unwrap().lines().map(str::to_string).collect()
    };

    let lines = run("QUERY count");
    assert_eq!(lines.len(), 2, "{lines:?}");
    let count = parse_flat(&lines[0]).unwrap();
    assert_eq!(count.get("rows").unwrap(), "4");
    assert_eq!(count.get("schedules").unwrap(), "2");
    let summary = parse_flat(&lines[1]).unwrap();
    assert_eq!(summary.get("type").unwrap(), "query_summary");
    assert_eq!(summary.get("store_rows").unwrap(), "4");

    let lines = run("QUERY select schedules=fac2 limit=1");
    assert_eq!(lines.len(), 2);
    let row = parse_flat(&lines[0]).unwrap();
    assert_eq!(row.get("schedule").unwrap(), "fac2");
    let summary = parse_flat(&lines[1]).unwrap();
    assert_eq!(summary.get("matched").unwrap(), "2", "limit must not hide matched");

    let lines = run("QUERY best-schedule");
    let row = parse_flat(&lines[0]).unwrap();
    assert!(row.contains_key("best_schedule"), "{row:?}");
    assert_eq!(row.get("schedules_compared").unwrap(), "2");
    assert_eq!(row.get("samples").unwrap(), "4", "seeds pool per scenario class");

    let lines = run("QUERY regret");
    assert_eq!(lines.len(), 3, "one row per schedule + summary: {lines:?}");
    for line in &lines[..2] {
        let row = parse_flat(line).unwrap();
        assert!(row.contains_key("mean_regret_pct"), "{row:?}");
        assert_eq!(row.get("oracle_groups").unwrap(), "2");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Every store-layer error code answers as one stable `ERR` line.
#[test]
fn query_verb_error_codes() {
    let dir = tmp_dir("query_errors");
    let svc = seeded_service(&dir);
    let one_err = |svc: &Service, line: &str, code: &str| {
        let mut out = Vec::new();
        svc.handle_query(line, &mut out);
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text.lines().count(), 1, "{line}: {text}");
        assert!(text.starts_with(&format!("ERR {code} ")), "{line}: {text}");
    };
    one_err(&svc, "QUERY frobnicate", "bad_query");
    one_err(&svc, "QUERY", "bad_query");
    one_err(&svc, "QUERY select by=workload", "bad_query");
    one_err(&svc, "QUERY select color=red", "bad_field");
    one_err(&svc, "QUERY select n=abc", "bad_value");
    one_err(&svc, "QUERY select n=1 n=2", "bad_request");
    // A service without a store answers no_store to any query.
    let bare = Service::new();
    one_err(&bare, "QUERY count", "no_store");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The full TCP path: BATCH populates the served store, QUERY reads it
/// back on the same connection, and errors stay in-band.
#[test]
fn query_verb_over_tcp() {
    let dir = tmp_dir("query_tcp");
    let store = Arc::new(ResultStore::open(&dir).unwrap());
    let svc = Arc::new(Service::new().with_store(store));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || serve_on_with(listener, 2, svc));

    let mut c = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());
    let mut line = String::new();

    writeln!(c, "BATCH schedules=fac2;gss n=300 workloads=uniform threads=2 seeds=1")
        .unwrap();
    loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(!line.starts_with("ERR"), "{line}");
        if line.contains("\"type\":\"summary\"") {
            break;
        }
    }

    writeln!(c, "QUERY best-schedule").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let row = parse_flat(&line).unwrap();
    assert!(row.contains_key("best_schedule"), "{line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"type\":\"query_summary\""), "{line}");

    writeln!(c, "QUERY nonsense").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR bad_query "), "{line}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A truncated segment fails the open with the stable coded error —
/// never a panic, never a silently shorter store.
#[test]
fn corrupt_segment_is_a_coded_open_error() {
    let dir = tmp_dir("corrupt_open");
    {
        let store = ResultStore::open(&dir).unwrap();
        let svc = Service::new().with_store(Arc::new(store));
        let mut out = Vec::new();
        svc.handle_batch("BATCH schedules=fac2 n=200 workloads=uniform seeds=1", &mut out);
    }
    let seg = dir.join("seg-000000.col");
    let mut bytes = std::fs::read(&seg).unwrap();
    let keep = bytes.len() - 5;
    bytes.truncate(keep);
    std::fs::write(&seg, &bytes).unwrap();
    let e = ResultStore::open(&dir).unwrap_err();
    assert_eq!(e.code, "store_corrupt");
    assert!(e.detail.contains("seg-000000.col"), "{e}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// CLI round trip: `uds sweep --store` twice (cold then all-hits) with
/// byte-identical report.csv, then `uds query` over the same store.
#[test]
fn cli_sweep_store_twice_then_query() {
    let exe = env!("CARGO_BIN_EXE_uds");
    let store_dir = tmp_dir("cli_store");
    let out1 = tmp_dir("cli_out1");
    let out2 = tmp_dir("cli_out2");
    let sweep = |out: &PathBuf| {
        std::process::Command::new(exe)
            .args([
                "sweep",
                "--schedules",
                "fac2;gss",
                "--n",
                "300",
                "--workloads",
                "uniform",
                "--threads",
                "2",
                "--seeds",
                "1,2",
                "--store",
                store_dir.to_str().unwrap(),
                "--out",
                out.to_str().unwrap(),
            ])
            .output()
            .expect("spawn uds sweep")
    };
    let cold = sweep(&out1);
    let cold_stdout = String::from_utf8_lossy(&cold.stdout).into_owned();
    assert!(cold.status.success(), "{cold_stdout}");
    assert!(
        cold_stdout.contains("store: hits=0 misses=4 appended=4"),
        "{cold_stdout}"
    );
    let warm = sweep(&out2);
    let warm_stdout = String::from_utf8_lossy(&warm.stdout).into_owned();
    assert!(warm.status.success(), "{warm_stdout}");
    assert!(
        warm_stdout.contains("store: hits=4 misses=0 appended=0"),
        "{warm_stdout}"
    );
    let csv1 = std::fs::read(out1.join("report.csv")).unwrap();
    let csv2 = std::fs::read(out2.join("report.csv")).unwrap();
    assert_eq!(csv1, csv2, "warm report.csv must be byte-identical");

    let query = std::process::Command::new(exe)
        .args([
            "query",
            "best-schedule",
            "--store",
            store_dir.to_str().unwrap(),
            "--workloads",
            "uniform",
        ])
        .output()
        .expect("spawn uds query");
    let q_stdout = String::from_utf8_lossy(&query.stdout).into_owned();
    assert!(query.status.success(), "{q_stdout}");
    assert!(q_stdout.contains("\"best_schedule\""), "{q_stdout}");
    assert!(q_stdout.contains("\"type\":\"query_summary\""), "{q_stdout}");
    for dir in [&store_dir, &out1, &out2] {
        let _ = std::fs::remove_dir_all(dir);
    }
}
