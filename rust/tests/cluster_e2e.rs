//! End-to-end cluster sweep fabric over loopback TCP: the acceptance
//! criteria of the cluster subsystem (ISSUE 6).
//!
//! * a 3-node cluster sweep's `report.csv` is byte-identical to a local
//!   sweep of the same grid, including with a ragged shard plan;
//! * killing a node mid-sweep (truncated stream, then connection
//!   refused) requeues its shards on healthy nodes and the merged
//!   artifact is still byte-identical;
//! * a >100k-scenario grid that a single-service `BATCH` refuses
//!   (`grid_too_large`, count named) completes through the coordinator,
//!   which re-applies the cap per shard.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use uds::cluster::{run_cluster_sweep, ClusterOptions};
use uds::eval::report::{Report, ScenarioResult, SweepSummary};
use uds::service::{serve_on, Service};
use uds::sweep::{run_sweep, SweepGrid};

fn spawn_service(pool_workers: usize) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || serve_on(listener, pool_workers));
    addr.to_string()
}

/// A node that dies mid-sweep: it serves exactly one connection with a
/// truncated result stream (two records, no summary), then refuses all
/// further connects — the coordinator must requeue its shards.
fn spawn_flaky_node() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        // Refuse everything after the first victim immediately.
        drop(listener);
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        let _ = reader.read_line(&mut line);
        let svc = Service::new();
        let mut full = Vec::new();
        svc.handle_batch(line.trim(), &mut full);
        // Stream the first two genuine records, then drop the socket.
        let cut = full
            .iter()
            .enumerate()
            .filter(|(_, &b)| b == b'\n')
            .map(|(i, _)| i + 1)
            .nth(1)
            .unwrap_or(full.len());
        let _ = stream.write_all(&full[..cut]);
    });
    addr.to_string()
}

/// The byte artifact under test: `report.csv` carries scenario rows
/// only, so cluster and local runs of one grid must render identically.
fn csv_of(results: Vec<ScenarioResult>) -> String {
    Report {
        meta: Vec::new(),
        summary: SweepSummary::default(),
        cluster: None,
        store: None,
        results,
    }
    .csv()
}

fn local_results(grid: &SweepGrid) -> (Vec<ScenarioResult>, SweepSummary) {
    run_sweep(&Service::new(), &grid.expand(), 2)
}

const GRID: &str = "BATCH workloads=lognormal;uniform \
schedules=fac2;gss;dynamic,16 n=500,1000 threads=2,4 seeds=1,2 workers=2";

#[test]
fn three_node_cluster_matches_local_byte_for_byte() {
    let grid = SweepGrid::parse_batch_line(GRID).unwrap();
    assert_eq!(grid.size(), 48);
    let nodes = vec![spawn_service(2), spawn_service(2), spawn_service(2)];
    let opts = ClusterOptions {
        // Ragged plan: 48 scenarios over shards of 7 (6 full + tail of 6).
        shard_size: 7,
        ..ClusterOptions::default()
    };
    let outcome = run_cluster_sweep(&grid, &nodes, &opts).unwrap();

    let (local, local_summary) = local_results(&grid);
    assert_eq!(
        csv_of(outcome.results),
        csv_of(local),
        "cluster report.csv must be byte-identical to the local sweep"
    );
    assert_eq!(outcome.summary.scenarios, 48);
    assert_eq!(
        outcome.summary.distinct_workloads,
        local_summary.distinct_workloads
    );

    let c = &outcome.cluster;
    assert_eq!(c.shards, 7);
    assert_eq!(c.shard_size, 7);
    assert_eq!(c.nodes.len(), 3);
    assert_eq!(c.retries, 0);
    assert_eq!(c.nodes.iter().map(|n| n.scenarios).sum::<u64>(), 48);
    assert_eq!(c.nodes.iter().map(|n| n.shards).sum::<u64>(), 7);
    assert!(c.nodes.iter().all(|n| !n.retired));

    // The cluster extension lands in report.json (and only there).
    let report = Report {
        meta: vec![("mode".into(), "cluster".into())],
        summary: outcome.summary,
        cluster: Some(outcome.cluster),
        store: None,
        results: Vec::new(),
    };
    let json = report.json();
    assert!(json.contains("\"cluster\":{"), "{json}");
    assert!(json.contains("\"nodes_total\":3"), "{json}");
}

/// Selector heads (expert rules + bandits) keep all their state in the
/// per-scenario `LoopRecord`, so sharding a selector grid across the
/// cluster cannot perturb any row: the merged report.csv must stay
/// byte-identical to a local sweep — the ISSUE 10 acceptance criterion.
#[test]
fn bandit_selector_grid_is_cluster_invariant() {
    let grid = SweepGrid::parse_batch_line(
        "BATCH workloads=phased:uniform:gaussian;burst:uniform \
         schedules=bandit:ucb;bandit:eps,0.2;auto n=400,800 threads=2,4 \
         seeds=3,4 workers=2",
    )
    .unwrap();
    assert_eq!(grid.size(), 48);
    let nodes = vec![spawn_service(2), spawn_service(2)];
    let opts = ClusterOptions { shard_size: 5, ..ClusterOptions::default() };
    let outcome = run_cluster_sweep(&grid, &nodes, &opts).unwrap();

    let (local, _) = local_results(&grid);
    assert_eq!(
        csv_of(outcome.results),
        csv_of(local),
        "selector grid report.csv must be byte-identical under --cluster"
    );
    assert_eq!(outcome.summary.scenarios, 48);
}

#[test]
fn node_killed_mid_sweep_requeues_and_stays_byte_identical() {
    let grid = SweepGrid::parse_batch_line(GRID).unwrap();
    let flaky = spawn_flaky_node();
    let nodes = vec![spawn_service(2), spawn_service(2), flaky.clone()];
    let opts = ClusterOptions {
        shard_size: 7,
        max_retries: 2,
        // Retire on the first failure so the `retired` flag is
        // deterministic regardless of how fast the healthy nodes drain
        // the plan.
        node_failures: 1,
        io_timeout: Duration::from_secs(10),
    };
    let outcome = run_cluster_sweep(&grid, &nodes, &opts).unwrap();

    let (local, _) = local_results(&grid);
    assert_eq!(
        csv_of(outcome.results),
        csv_of(local),
        "a mid-sweep node death must not change the merged artifact"
    );
    let c = &outcome.cluster;
    assert!(c.retries >= 1, "the dead node's shard was requeued: {c:?}");
    let dead = c.nodes.iter().find(|n| n.addr == flaky).unwrap();
    assert!(dead.failures >= 1, "{dead:?}");
    assert!(dead.retired, "{dead:?}");
    assert_eq!(c.nodes.iter().map(|n| n.scenarios).sum::<u64>(), 48);
}

#[test]
fn over_cap_grid_refused_by_one_service_but_completes_via_cluster() {
    // 201 n-values x 501 seeds = 100,701 scenarios: over the 100k
    // single-request cap.
    let ns: String =
        (10..211).map(|i| i.to_string()).collect::<Vec<_>>().join(",");
    let seeds: String =
        (0..501).map(|i| i.to_string()).collect::<Vec<_>>().join(",");
    let line = format!(
        "BATCH workloads=uniform schedules=fac2 n={ns} seeds={seeds} \
threads=2 workers=2"
    );
    let nodes = vec![spawn_service(2), spawn_service(2), spawn_service(2)];

    // A single service refuses the whole grid, naming the count.
    let mut c = TcpStream::connect(&nodes[0]).unwrap();
    writeln!(c, "{line}").unwrap();
    c.shutdown(std::net::Shutdown::Write).unwrap();
    let mut resp = String::new();
    BufReader::new(c).read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("ERR grid_too_large"), "{resp}");
    assert!(resp.contains("100701"), "count named in the refusal: {resp}");

    // The coordinator parses the same grid uncapped and shards it.
    let body = line.strip_prefix("BATCH").unwrap().trim();
    let pairs: Vec<(&str, &str)> = body
        .split_whitespace()
        .map(|tok| tok.split_once('=').unwrap())
        .collect();
    let grid = SweepGrid::from_pairs_uncapped(pairs).unwrap();
    assert_eq!(grid.size(), 100_701);
    let opts = ClusterOptions { shard_size: 25_000, ..ClusterOptions::default() };
    let outcome = run_cluster_sweep(&grid, &nodes, &opts).unwrap();

    assert_eq!(outcome.summary.scenarios, 100_701);
    assert_eq!(outcome.results.len(), 100_701);
    for (i, r) in outcome.results.iter().enumerate() {
        assert_eq!(r.id, i as u64, "merged ids dense and ordered");
    }
    assert_eq!(outcome.cluster.shards, 5, "ceil(100701 / 25000)");

    // Spot-check merged records against direct local simulation.
    let svc = Service::new();
    for id in [0u64, 1, 25_000, 99_999, 100_700] {
        let (one, _) = run_sweep(&svc, &[grid.scenario_at(id)], 1);
        assert_eq!(one[0], outcome.results[id as usize], "scenario {id}");
    }
}
