//! ISSUE 4 acceptance: a declare-registered user-defined schedule runs
//! end-to-end **by name** — through a local sweep (the `uds sweep`
//! engine) and through a `BATCH` request over TCP — producing chunk
//! sequences and simulation results bit-identical to its native builtin
//! counterpart (`static,16`).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Mutex, OnceLock};

use uds::coordinator::declare::{Args, DeclarationBuilder, Registry};
use uds::coordinator::{drain_chunks, LoopRecord, LoopSpec, TeamSpec};
use uds::eval::report::{parse_flat, ScenarioResult};
use uds::schedules::registry::ScheduleRegistry;
use uds::schedules::ScheduleSpec;
use uds::service::{serve_on, Service};
use uds::sweep::{run_sweep, SweepGrid};

/// The published name of the user-defined schedule under test.
const UDS_NAME: &str = "mystatic16";
/// Its native builtin twin.
const NATIVE: &str = "static,16";
const CHUNK: i64 = 16;

/// The paper's Fig. 2 `loop_record_t`: all scheduling state lives in the
/// user arguments, built fresh per scheduler instance by the publish
/// argument maker.
#[derive(Default)]
struct LoopRecordT {
    lb: i64,
    ub: i64,
    incr: i64,
    chunksz: i64,
    next_lb: Vec<i64>,
}

/// Declare `mystatic16` (§4.2 style) and publish it into the global
/// schedule registry, once per process.
fn register_uds() {
    static ONCE: OnceLock<()> = OnceLock::new();
    ONCE.get_or_init(|| {
        let decl = Registry::new();
        decl.declare(
            DeclarationBuilder::schedule(UDS_NAME)
                .arguments(2)
                .init(|lb, ub, incr, _chunk, nthreads, args| {
                    let lr = args.arg::<Mutex<LoopRecordT>>(0);
                    let chunksz = *args.arg::<i64>(1);
                    let mut lr = lr.lock().unwrap();
                    lr.lb = lb;
                    lr.ub = ub;
                    lr.incr = incr;
                    lr.chunksz = chunksz;
                    lr.next_lb = (0..nthreads as i64)
                        .map(|t| lb + t * chunksz * incr)
                        .collect();
                })
                .next(|lower, upper, incr, tid, _fb, args| {
                    let lr = args.arg::<Mutex<LoopRecordT>>(0);
                    let mut lr = lr.lock().unwrap();
                    if lr.next_lb[tid] >= lr.ub {
                        return false;
                    }
                    *lower = lr.next_lb[tid];
                    let step = lr.chunksz * lr.incr;
                    *upper = (lr.next_lb[tid] + step).min(lr.ub);
                    *incr = lr.incr;
                    let p = lr.next_lb.len() as i64;
                    lr.next_lb[tid] += p * step;
                    true
                })
                .build(),
        )
        .unwrap();
        decl.publish(
            ScheduleRegistry::global(),
            UDS_NAME,
            "declare-style twin of static,16 (ISSUE 4 acceptance)",
            || Args::new().with(Mutex::new(LoopRecordT::default())).with(CHUNK),
        )
        .unwrap();
    });
}

/// A scenario result reduced to its physics: identity fields cleared so
/// a user-defined schedule row compares bit-for-bit against its native
/// twin row.
fn physics(r: &ScenarioResult) -> ScenarioResult {
    let mut r = r.clone();
    r.id = 0;
    r.schedule = String::new();
    r
}

#[test]
fn declared_uds_resolves_by_name_and_matches_native_chunks() {
    register_uds();
    let uds = ScheduleSpec::parse(UDS_NAME).unwrap();
    assert_eq!(uds.label(), UDS_NAME);
    let native = ScheduleSpec::parse(NATIVE).unwrap();
    for (n, p) in [(1000u64, 4usize), (333, 3), (37, 5)] {
        let drain = |spec: &ScheduleSpec| {
            let mut s = spec.build();
            drain_chunks(
                &mut *s,
                &LoopSpec::upto(n),
                &TeamSpec::uniform(p),
                &mut LoopRecord::default(),
            )
        };
        assert_eq!(drain(&uds), drain(&native), "n={n} p={p}");
    }
}

#[test]
fn declared_uds_sweeps_by_name_bit_identical_to_native() {
    register_uds();
    let line = format!(
        "BATCH workloads=uniform,lognormal schedules={UDS_NAME};{NATIVE} \
n=500,1000 threads=2,4 seeds=1 workers=4"
    );
    let grid = SweepGrid::parse_batch_line(&line).unwrap();
    assert!(grid.to_batch_line().contains(UDS_NAME));
    let scenarios = grid.expand();
    assert_eq!(scenarios.len(), 16);
    let (results, summary) = run_sweep(&Service::new(), &scenarios, 4);
    assert_eq!(summary.scenarios, 16);
    assert_eq!(results.len(), 16);
    // Expansion order is workloads x n x seeds x schedules x threads
    // (threads innermost): in each block of 4, rows 0..2 are the UDS
    // schedule and rows 2..4 its native twin at the same thread counts.
    for block in results.chunks(4) {
        assert_eq!(block[0].schedule, UDS_NAME);
        assert_eq!(block[2].schedule, NATIVE);
        assert_eq!(physics(&block[0]), physics(&block[2]), "threads=2 pair");
        assert_eq!(physics(&block[1]), physics(&block[3]), "threads=4 pair");
    }
}

#[test]
fn declared_uds_runs_over_tcp_batch_by_name() {
    register_uds();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || serve_on(listener, 2));

    let mut c = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());
    writeln!(
        c,
        "BATCH workloads=gaussian schedules={UDS_NAME};{NATIVE} n=700 threads=3 seeds=2"
    )
    .unwrap();
    let mut lines = Vec::new();
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0, "connection closed before the summary record: {lines:?}");
        let done = line.contains("\"type\":\"summary\"") || line.starts_with("ERR");
        lines.push(line.trim().to_string());
        if done {
            break;
        }
    }
    assert_eq!(lines.len(), 3, "{lines:?}");
    let uds = ScenarioResult::from_flat(&parse_flat(&lines[0]).unwrap()).unwrap();
    let native = ScenarioResult::from_flat(&parse_flat(&lines[1]).unwrap()).unwrap();
    assert_eq!(uds.schedule, UDS_NAME);
    assert_eq!(native.schedule, NATIVE);
    assert_eq!(physics(&uds), physics(&native), "wire results bit-identical");

    // The same connection answers single jobs by UDS name...
    writeln!(c, "schedule={UDS_NAME} n=400 threads=2 workload=uniform").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let expect = format!("ok schedule={UDS_NAME} ");
    assert!(line.starts_with(&expect), "{line}");

    // ...and unknown names keep the stable error surface.
    writeln!(c, "BATCH schedules=never_registered n=100").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR bad_schedule"), "{line}");
    writeln!(c, "schedule=never_registered n=100").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR bad_schedule"), "{line}");
}
