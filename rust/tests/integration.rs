//! Integration tests: executors x schedulers x workloads x history.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use uds::coordinator::{
    parallel_for, ExecOptions, HistoryArena, LoopRecord, LoopSpec, TeamSpec,
};
use uds::schedules::{AwfVariant, ScheduleSpec};
use uds::sim::{simulate, Heterogeneous, NoVariability, NoiseBursts, SimConfig};
use uds::workload::{CostModel, TraceCost, WorkloadClass};

/// Every roster schedule, on the REAL thread-team executor, must execute
/// every iteration exactly once.
#[test]
fn real_executor_exactly_once_all_schedules() {
    let n = 10_007u64; // prime, to stress remainders
    let team = TeamSpec::uniform(4);
    for spec in ScheduleSpec::roster() {
        let hits: Vec<AtomicU8> = (0..n).map(|_| AtomicU8::new(0)).collect();
        let history = HistoryArena::new();
        let stats = parallel_for(
            &LoopSpec::upto(n),
            &team,
            &*spec.factory(),
            &history,
            &ExecOptions::default(),
            |i, _| {
                hits[i as usize].fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(stats.iterations, n, "{}", spec.label());
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(
                h.load(Ordering::Relaxed),
                1,
                "{}: iteration {i} ran wrong number of times",
                spec.label()
            );
        }
    }
}

/// Simulator and real executor must agree on the *chunk count* for
/// deterministic (dequeue-order-independent) schedules.
#[test]
fn sim_and_real_agree_on_chunk_counts() {
    let n = 4096u64;
    let team = TeamSpec::uniform(4);
    let costs = TraceCost::new(vec![50; n as usize]);
    for spec in [
        ScheduleSpec::Static { chunk: Some(32) },
        ScheduleSpec::Dynamic { chunk: 32 },
        ScheduleSpec::Tss { params: None },
        ScheduleSpec::Fac2,
    ] {
        let sim_stats = simulate(
            &LoopSpec::upto(n),
            &team,
            &*spec.factory(),
            &costs,
            &NoVariability,
            &mut LoopRecord::default(),
            &SimConfig::default(),
        );
        let history = HistoryArena::new();
        let real_stats = parallel_for(
            &LoopSpec::upto(n),
            &team,
            &*spec.factory(),
            &history,
            &ExecOptions::default(),
            |_, _| {},
        );
        assert_eq!(
            sim_stats.chunks,
            real_stats.chunks,
            "{}: sim {} vs real {}",
            spec.label(),
            sim_stats.chunks,
            real_stats.chunks
        );
    }
}

/// Strided and negative-stride loops pass logical indices to the body.
#[test]
fn strided_loops_all_schedules() {
    use std::sync::Mutex;
    let spec_up = LoopSpec::new(100, 150, 7).unwrap(); // 100,107,...,149 (8 iters)
    let spec_down = LoopSpec::new(50, 10, -5).unwrap(); // 50,45,...,15 (8 iters)
    let team = TeamSpec::uniform(3);
    for sched in ScheduleSpec::roster() {
        for (loop_spec, expect) in [
            (spec_up, (0..8).map(|k| 100 + 7 * k).collect::<Vec<i64>>()),
            (spec_down, (0..8).map(|k| 50 - 5 * k).collect::<Vec<i64>>()),
        ] {
            let seen = Mutex::new(Vec::new());
            let history = HistoryArena::new();
            parallel_for(
                &loop_spec,
                &team,
                &*sched.factory(),
                &history,
                &ExecOptions::default(),
                |i, _| seen.lock().unwrap().push(i),
            );
            let mut v = seen.into_inner().unwrap();
            v.sort();
            let mut e = expect.clone();
            e.sort();
            assert_eq!(v, e, "{} on {loop_spec:?}", sched.label());
        }
    }
}

/// AWF learns heterogeneous speeds across invocations: by the 4th
/// invocation its makespan must beat oblivious FAC2 on a 4x-skewed team.
#[test]
fn awf_adapts_to_heterogeneity() {
    let n = 20_000u64;
    let p = 4usize;
    let costs = WorkloadClass::Uniform.model(n, 1_000.0, 7);
    let het = Heterogeneous::new(vec![1.0, 1.0, 1.0, 8.0]);
    let cfg = SimConfig { dequeue_overhead_ns: 100, trace: false };

    let run_seq = |spec: ScheduleSpec, invocations: usize| -> u64 {
        let mut rec = LoopRecord::default();
        let mut last = 0;
        for _ in 0..invocations {
            let stats = simulate(
                &LoopSpec::upto(n),
                &TeamSpec::uniform(p),
                &*spec.factory(),
                &costs,
                &het,
                &mut rec,
                &cfg,
            );
            last = stats.makespan_ns;
        }
        last
    };

    let awf = run_seq(ScheduleSpec::Awf { variant: AwfVariant::B }, 5);
    let static_ms = run_seq(ScheduleSpec::Static { chunk: None }, 5);
    // Static block gives every thread n/4; the slow threads dominate.
    // AWF should be at least 1.5x better.
    assert!(
        (static_ms as f64) > 1.5 * awf as f64,
        "awf {awf} vs static {static_ms}"
    );
}

/// The history arena preserves per-call-site records across invocations
/// and isolates distinct call sites.
#[test]
fn history_isolated_per_call_site() {
    let team = TeamSpec::uniform(2);
    let history = HistoryArena::new();
    let f = ScheduleSpec::Fac2.factory();
    for (site, n) in [("a", 100u64), ("a", 100), ("b", 50)] {
        parallel_for(
            &LoopSpec::upto(n),
            &team,
            &*f,
            &history,
            &ExecOptions { call_site: Some(site.into()), ..Default::default() },
            |_, _| {},
        );
    }
    assert_eq!(history.record("a").lock().unwrap().invocations, 2);
    assert_eq!(history.record("b").lock().unwrap().invocations, 1);
    assert_eq!(history.len(), 2);
}

/// Tuned-dynamic converges: across invocations on an overhead-dominated
/// workload the tuner must grow k and reduce total dequeues.
#[test]
fn tuned_dynamic_reduces_dequeues_over_time() {
    let n = 50_000u64;
    let costs = WorkloadClass::Uniform.model(n, 50.0, 1);
    let cfg = SimConfig { dequeue_overhead_ns: 2_000, trace: false };
    let spec = ScheduleSpec::Tuned { k0: 1 };
    let mut rec = LoopRecord::default();
    let mut dequeues = Vec::new();
    for _ in 0..8 {
        let stats = simulate(
            &LoopSpec::upto(n),
            &TeamSpec::uniform(4),
            &*spec.factory(),
            &costs,
            &NoVariability,
            &mut rec,
            &cfg,
        );
        dequeues.push(stats.total_dequeues());
    }
    assert!(
        dequeues.last().unwrap() * 4 < dequeues[0],
        "tuner failed to grow k: {dequeues:?}"
    );
}

/// Noise hurts static more than the adaptive/dynamic families (the E5
/// claim, asserted at integration level).
#[test]
fn noise_hurts_static_more_than_self_scheduling() {
    let n = 20_000u64;
    let costs = WorkloadClass::Uniform.model(n, 1_000.0, 3);
    let noise = NoiseBursts::new(200_000, 0.4, 0.2, 9);
    let cfg = SimConfig { dequeue_overhead_ns: 100, trace: false };
    let run = |spec: ScheduleSpec| {
        simulate(
            &LoopSpec::upto(n),
            &TeamSpec::uniform(4),
            &*spec.factory(),
            &costs,
            &noise,
            &mut LoopRecord::default(),
            &cfg,
        )
        .makespan_ns
    };
    let st = run(ScheduleSpec::Static { chunk: None });
    let ss = run(ScheduleSpec::Dynamic { chunk: 16 });
    assert!(st > ss, "static {st} should exceed dynamic,16 {ss} under noise");
}

/// Empty loops, single iterations and single threads never hang or panic.
#[test]
fn degenerate_geometries() {
    for spec in ScheduleSpec::roster() {
        for (n, p) in [(0u64, 1usize), (0, 8), (1, 1), (1, 8), (2, 2)] {
            let counter = AtomicU64::new(0);
            let history = HistoryArena::new();
            let stats = parallel_for(
                &LoopSpec::upto(n),
                &TeamSpec::uniform(p),
                &*spec.factory(),
                &history,
                &ExecOptions::default(),
                |_, _| {
                    counter.fetch_add(1, Ordering::Relaxed);
                },
            );
            assert_eq!(counter.load(Ordering::Relaxed), n, "{} n={n} p={p}", spec.label());
            assert_eq!(stats.iterations, n);
        }
    }
}

/// Trace mode records a complete, ordered chunk log.
#[test]
fn trace_mode_complete() {
    let n = 1000u64;
    let costs = WorkloadClass::Gaussian.model(n, 200.0, 5);
    let stats = simulate(
        &LoopSpec::upto(n),
        &TeamSpec::uniform(4),
        &*ScheduleSpec::Guided { min_chunk: 1 }.factory(),
        &costs,
        &NoVariability,
        &mut LoopRecord::default(),
        &SimConfig { dequeue_overhead_ns: 10, trace: true },
    );
    let total: u64 = stats.trace.iter().map(|c| c.chunk.len).sum();
    assert_eq!(total, n);
    assert!(stats.trace.windows(2).all(|w| w[0].start_ns <= w[1].start_ns));
}

/// The WF2/E7 claim: on a heterogeneous team, user-weighted WF2 beats
/// weight-oblivious FAC2.
#[test]
fn wf2_beats_fac2_on_heterogeneous_team() {
    let n = 50_000u64;
    let speeds = vec![1.0, 1.0, 2.0, 4.0];
    let costs = WorkloadClass::Uniform.model(n, 1_000.0, 11);
    let het = Heterogeneous::new(speeds.clone());
    let cfg = SimConfig { dequeue_overhead_ns: 100, trace: false };
    let wf2 = simulate(
        &LoopSpec::upto(n),
        &TeamSpec::weighted(&speeds),
        &*ScheduleSpec::Wf2.factory(),
        &costs,
        &het,
        &mut LoopRecord::default(),
        &cfg,
    );
    let fac2 = simulate(
        &LoopSpec::upto(n),
        &TeamSpec::uniform(4),
        &*ScheduleSpec::Fac2.factory(),
        &costs,
        &het,
        &mut LoopRecord::default(),
        &cfg,
    );
    assert!(
        wf2.makespan_ns < fac2.makespan_ns,
        "wf2 {} vs fac2 {}",
        wf2.makespan_ns,
        fac2.makespan_ns
    );
}

/// Auto-selection settles on static for regular loops and improves on
/// its exploration invocation.
#[test]
fn auto_selects_static_for_regular_loop() {
    let n = 10_000u64;
    let costs = WorkloadClass::Uniform.model(n, 500.0, 2);
    let cfg = SimConfig { dequeue_overhead_ns: 500, trace: false };
    let spec = ScheduleSpec::Auto;
    let mut rec = LoopRecord::default();
    let mut makespans = Vec::new();
    for _ in 0..4 {
        let stats = simulate(
            &LoopSpec::upto(n),
            &TeamSpec::uniform(4),
            &*spec.factory(),
            &costs,
            &NoVariability,
            &mut rec,
            &cfg,
        );
        makespans.push(stats.makespan_ns);
        rec.invocations = rec.invocations.max(1);
    }
    assert_eq!(rec.selected.as_deref(), Some("static"));
    assert!(
        *makespans.last().unwrap() < makespans[0],
        "selection should improve on exploration: {makespans:?}"
    );
}
