//! Robustness and failure-injection tests: panicking bodies, corrupt
//! artifacts, malformed inputs, feedback plumbing, and literature-exact
//! sequence checks.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use uds::coordinator::{
    parallel_for, ChunkFeedback, ExecOptions, HistoryArena, LoopRecord, LoopSpec,
    ScheduleFactory, Scheduler, TeamSpec,
};
use uds::schedules::ScheduleSpec;

// ---------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------

/// A panicking loop body must propagate (not deadlock or get swallowed).
#[test]
fn body_panic_propagates() {
    let result = std::panic::catch_unwind(|| {
        let history = HistoryArena::new();
        parallel_for(
            &LoopSpec::upto(100),
            &TeamSpec::uniform(4),
            &*ScheduleSpec::Dynamic { chunk: 4 }.factory(),
            &history,
            &ExecOptions::default(),
            |i, _| {
                if i == 37 {
                    panic!("injected body failure");
                }
            },
        )
    });
    assert!(result.is_err(), "panic must propagate out of parallel_for");
}

/// Corrupt HLO artifact: the runtime must return an error, not crash.
#[test]
fn corrupt_artifact_is_an_error() {
    use uds::runtime::WorkRuntime;
    let dir = std::env::temp_dir().join("uds_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "chunk_rows=128\nfeature_dim=64\ndepth_classes=1\n\
         artifact_pattern=work_d{depth}.hlo.txt\nrtol=1e-5\natol=1e-5\n",
    )
    .unwrap();
    std::fs::write(dir.join("work_d1.hlo.txt"), "HloModule utterly_bogus garbage")
        .unwrap();
    assert!(WorkRuntime::load(&dir).is_err());
}

/// Missing manifest: clean error.
#[test]
fn missing_manifest_is_an_error() {
    use uds::runtime::Manifest;
    let dir = std::env::temp_dir().join("uds_nonexistent_dir_xyz");
    assert!(Manifest::load(&dir).is_err());
}

/// Malformed golden file: clean error.
#[test]
fn malformed_golden_is_an_error() {
    use uds::runtime::Golden;
    let dir = std::env::temp_dir().join("uds_bad_golden");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("golden.txt"), "x=1.0 not_a_float\nw=1\nb=1\ndepths=1\n")
        .unwrap();
    assert!(Golden::load(&dir).is_err());
}

/// A UDS whose dequeue reports a chunk outside the iteration space is a
/// *user* bug; the frontends normalize in debug builds, and verify_cover
/// in tests catches it.  Here: a schedule returning an inverted chunk is
/// treated as done (no chunk), never an infinite loop.
#[test]
fn inverted_chunk_report_terminates() {
    use uds::coordinator::lambda::UdsBuilder;
    let f = UdsBuilder::named("inverted")
        .dequeue(|ctx, _, _, _, sink| {
            // end before start: must convert to "no chunk".
            sink.chunk_start(ctx.loop_start() + 5);
            sink.chunk_end(ctx.loop_start() + 5);
        })
        .build();
    let mut s = f.build();
    let mut rec = LoopRecord::default();
    s.start(&LoopSpec::upto(10), &TeamSpec::uniform(1), &mut rec);
    assert!(s.next(0, None).is_none());
}

// ---------------------------------------------------------------------
// Feedback plumbing (the merged begin/end-loop-body hooks)
// ---------------------------------------------------------------------

/// A spy scheduler verifying the executor hands back feedback for the
/// exact chunk a thread just executed.
struct SpyScheduler {
    n: u64,
    cursor: AtomicU64,
    observed: Mutex<Vec<(usize, u64, u64)>>, // (tid, chunk.first, elapsed>0)
}

impl Scheduler for SpyScheduler {
    fn name(&self) -> String {
        "spy".into()
    }
    fn start(&mut self, l: &LoopSpec, _t: &TeamSpec, _r: &mut LoopRecord) {
        self.n = l.iter_count();
        self.cursor = AtomicU64::new(0);
    }
    fn next(&self, tid: usize, fb: Option<&ChunkFeedback>) -> Option<uds::Chunk> {
        if let Some(fb) = fb {
            assert_eq!(fb.tid, tid, "feedback must be the caller's own chunk");
            self.observed.lock().unwrap().push((
                tid,
                fb.chunk.first,
                fb.elapsed_ns,
            ));
        }
        let i = self.cursor.fetch_add(8, Ordering::Relaxed);
        (i < self.n).then(|| uds::Chunk::new(i, 8.min(self.n - i)))
    }
    fn finish(&mut self, _t: &TeamSpec, _r: &mut LoopRecord) {}
    fn is_adaptive(&self) -> bool {
        true
    }
}

#[test]
fn executor_feeds_back_every_chunk() {
    struct SpyFactory(std::sync::Arc<Mutex<Vec<(usize, u64, u64)>>>);
    impl ScheduleFactory for SpyFactory {
        fn name(&self) -> String {
            "spy".into()
        }
        fn build(&self) -> Box<dyn Scheduler> {
            Box::new(SpyScheduler {
                n: 0,
                cursor: AtomicU64::new(0),
                observed: Mutex::new(Vec::new()),
            })
        }
    }
    // Use drain_chunks-style single instance through parallel_for by
    // checking RunStats instead: every chunk but each thread's last gets
    // fed back, so observed >= chunks - P.
    let history = HistoryArena::new();
    let stats = parallel_for(
        &LoopSpec::upto(256),
        &TeamSpec::uniform(4),
        &SpyFactory(Default::default()),
        &history,
        &ExecOptions::default(),
        |_, _| {
            std::hint::black_box(());
        },
    );
    assert_eq!(stats.iterations, 256);
    assert_eq!(stats.chunks, 32);
}

// ---------------------------------------------------------------------
// Literature-exact sequences
// ---------------------------------------------------------------------

/// TSS canonical parameters from Tzen & Ni: N=1000, P=4 -> first=125,
/// linear decrement, all chunks cover exactly.
#[test]
fn tss_tzen_ni_example() {
    let seq = uds::schedules::Tss::sequence(1000, 4, None);
    assert_eq!(seq[0], 125);
    assert_eq!(seq.iter().sum::<u64>(), 1000);
    // Linear: second differences are ~0 (within rounding).
    let d: Vec<i64> = seq.windows(2).map(|w| w[0] as i64 - w[1] as i64).collect();
    for w in d[..d.len().saturating_sub(2)].windows(2) {
        assert!((w[0] - w[1]).abs() <= 1, "not linear: {seq:?}");
    }
}

/// GSS from Polychronopoulos & Kuck: N=100, P=4 produces
/// 25,19,14,11,8,6,5,3,3,2,1,1,1,1 (sum 100).
#[test]
fn gss_polychronopoulos_kuck_example() {
    let seq = uds::schedules::Gss::sequence(100, 4, 1);
    assert_eq!(&seq[..8], &[25, 19, 14, 11, 8, 6, 5, 3]);
    assert_eq!(seq.iter().sum::<u64>(), 100);
}

/// Factoring from Flynn Hummel et al.: with x=2 (FAC2), N=1000, P=4:
/// batches 125x4, 63x4, 31x4(+1 rounding tail)...
#[test]
fn fac2_hummel_example() {
    let seq = uds::schedules::Fac2::sequence(1000, 4);
    assert_eq!(&seq[..4], &[125, 125, 125, 125]);
    assert_eq!(&seq[4..8], &[63, 63, 63, 63]);
    assert_eq!(seq.iter().sum::<u64>(), 1000);
}

/// Kruskal-Weiss FSC: the canonical formula value for a known input.
#[test]
fn fsc_kruskal_weiss_formula() {
    // k = (sqrt(2)*N*h / (sigma*P*sqrt(ln P)))^(2/3)
    let k = uds::schedules::Fsc::k_opt(1_000_000, 16, 1000.0, 500.0);
    let expect = ((2.0f64).sqrt() * 1e6 * 1000.0
        / (500.0 * 16.0 * (16.0f64).ln().sqrt()))
    .powf(2.0 / 3.0);
    assert!((k as f64 - expect).abs() <= 1.0, "{k} vs {expect}");
}

// ---------------------------------------------------------------------
// Service robustness
// ---------------------------------------------------------------------

/// The CLI binary parses and runs a simulated loop end-to-end.
#[test]
fn cli_run_smoke() {
    let exe = env!("CARGO_BIN_EXE_uds");
    let out = std::process::Command::new(exe)
        .args(["run", "--schedule", "fac2", "--n", "5000", "--threads", "4"])
        .output()
        .expect("spawn uds");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("makespan="), "{text}");
}

#[test]
fn cli_eval_e1_smoke() {
    let exe = env!("CARGO_BIN_EXE_uds");
    let dir = std::env::temp_dir().join("uds_cli_eval");
    let out = std::process::Command::new(exe)
        .args(["eval", "e1", "--n", "2000", "--threads", "4"])
        .arg("--out")
        .arg(&dir)
        .output()
        .expect("spawn uds");
    assert!(out.status.success());
    assert!(dir.join("e1_chunk_evolution.csv").exists());
}

#[test]
fn cli_rejects_bad_schedule() {
    let exe = env!("CARGO_BIN_EXE_uds");
    let out = std::process::Command::new(exe)
        .args(["run", "--schedule", "quantum-leap"])
        .output()
        .expect("spawn uds");
    assert!(!out.status.success());
}
