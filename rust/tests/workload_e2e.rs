//! ISSUE 5 acceptance: a registry-resolved **composite workload**
//! (`phased:increasing:uniform,0.5`) and a non-calm **variability
//! spec** (`hetero:1,1,2,4`, plus a noise model) run *by label* through
//! a local sweep, a `BATCH` request over TCP, and the `uds` CLI —
//! producing bit-identical result streams for 1 vs 8 sweep workers.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use uds::eval::report::{parse_flat, ScenarioResult};
use uds::service::{serve_on, Service};
use uds::sweep::{run_sweep, SweepGrid};

/// The acceptance grid: 3 variability x 2 workloads x 2 n x 2 seeds x
/// 3 schedules x 1 thread count = 72 scenarios.
const GRID: &str = "BATCH \
workloads=phased:increasing:uniform,0.5;mix:gaussian:lognormal,frac=0.25 \
variability=calm;hetero:1,1,2,4;noise:0.2,0.25,7 \
schedules=fac2;gss;dynamic,16 n=600,1200 threads=4 seeds=1,2 workers=1";

const PHASED: &str = "phased:increasing:uniform,switch=0.5";

fn wire(results: &[ScenarioResult]) -> Vec<String> {
    results.iter().map(|r| r.json_line()).collect()
}

#[test]
fn composite_workloads_and_variability_sweep_locally_worker_invariant() {
    let grid = SweepGrid::parse_batch_line(GRID).unwrap();
    let scenarios = grid.expand();
    assert_eq!(scenarios.len(), 72);

    let (one, s1) = run_sweep(&Service::new(), &scenarios, 1);
    let (eight, _) = run_sweep(&Service::new(), &scenarios, 8);
    assert_eq!(s1.scenarios, 72);
    assert_eq!(
        wire(&one),
        wire(&eight),
        "1 vs 8 workers must stream bit-identical results"
    );

    // Records carry the canonical registry labels.
    assert!(one.iter().any(|r| r.workload == PHASED), "phased label missing");
    assert!(
        one.iter().any(|r| r.workload == "mix:gaussian:lognormal,frac=0.25"),
        "mix label missing"
    );
    assert!(
        one.iter().any(|r| r.variability == "hetero:1,1,2,4"),
        "hetero label missing"
    );
    assert!(
        one.iter().any(|r| r.variability == "noise:0.2,0.25,7,200000"),
        "noise label missing"
    );

    // Variability reaches the physics: the same (workload, schedule, n,
    // seed) scenario differs between calm and hetero machines, and the
    // 2x/4x threads make the hetero run finish sooner.
    let calm = one
        .iter()
        .find(|r| r.variability == "calm" && r.workload == PHASED)
        .unwrap();
    let hetero = one
        .iter()
        .find(|r| {
            r.variability == "hetero:1,1,2,4"
                && r.workload == calm.workload
                && r.schedule == calm.schedule
                && r.n == calm.n
                && r.seed == calm.seed
        })
        .unwrap();
    assert!(
        hetero.makespan_ns < calm.makespan_ns,
        "hetero {} !< calm {}",
        hetero.makespan_ns,
        calm.makespan_ns
    );

    // The distinct-workload cache dedups across the variability axis:
    // 2 workloads x 2 n x 2 seeds = 8 indexes for 72 scenarios.
    assert_eq!(s1.distinct_workloads, 8);
    assert_eq!(s1.index_builds, 8);
}

#[test]
fn composite_workloads_and_variability_run_over_tcp_batch() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || serve_on(listener, 2));

    let mut c = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());
    writeln!(c, "{GRID}").unwrap();
    let mut lines = Vec::new();
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0, "connection closed early: {} lines", lines.len());
        let done = line.contains("\"type\":\"summary\"") || line.starts_with("ERR");
        lines.push(line.trim().to_string());
        if done {
            break;
        }
    }
    assert_eq!(lines.len(), 73, "72 results + summary: {:?}", lines.last());

    // The TCP stream is bit-identical to the local sweep's wire form.
    let grid = SweepGrid::parse_batch_line(GRID).unwrap();
    let (local, _) = run_sweep(&Service::new(), &grid.expand(), 8);
    assert_eq!(lines[..72], wire(&local)[..], "TCP stream != local sweep");

    // Records parse back with the composite/variability labels intact.
    let rec = ScenarioResult::from_flat(&parse_flat(&lines[0]).unwrap()).unwrap();
    assert_eq!(rec.workload, PHASED);
    assert_eq!(rec.variability, "calm");

    // The same connection serves a single composite job under noise...
    writeln!(
        c,
        "schedule=gss n=500 threads=4 workload={PHASED} variability=noise:0.2,0.25,7"
    )
    .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ok schedule=guided "), "{line}");

    // ...and malformed labels keep the stable error surface.
    writeln!(c, "schedule=gss n=500 workload=phased:increasing").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR bad_workload"), "{line}");
    writeln!(c, "schedule=gss n=500 variability=hetero:").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR bad_variability"), "{line}");
}

#[test]
fn composite_workloads_and_variability_run_through_the_cli() {
    let uds = env!("CARGO_BIN_EXE_uds");

    // `uds run` executes a composite workload on a heterogeneous
    // simulated machine by label.
    let out = std::process::Command::new(uds)
        .args([
            "run",
            "--schedule",
            "fac2",
            "--n",
            "4000",
            "--threads",
            "4",
            "--workload",
            "phased:increasing:uniform,0.5",
            "--variability",
            "hetero:1,1,2,4",
            "--seed",
            "7",
        ])
        .output()
        .expect("spawn uds run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("schedule=fac2"), "{stdout}");
    assert!(stdout.contains("makespan="), "{stdout}");

    // Unknown labels fail with the parse detail on stderr.
    let bad = std::process::Command::new(uds)
        .args(["run", "--workload", "phased:increasing", "--n", "100"])
        .output()
        .expect("spawn uds run");
    assert!(!bad.status.success());
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("workload"), "{stderr}");

    // `uds sweep` writes report artifacts carrying the canonical labels.
    let out_dir = std::env::temp_dir()
        .join(format!("uds_workload_e2e_sweep_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = std::process::Command::new(uds)
        .args([
            "sweep",
            "--schedules",
            "fac2;gss",
            "--n",
            "500",
            "--workloads",
            "phased:increasing:uniform,0.5",
            "--variability",
            "calm;hetero:1,1,2,4",
            "--threads",
            "4",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .expect("spawn uds sweep");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let csv = std::fs::read_to_string(out_dir.join("report.csv")).unwrap();
    assert!(csv.contains(PHASED), "{csv}");
    assert!(csv.contains("hetero:1,1,2,4"), "{csv}");
    assert_eq!(csv.lines().count(), 1 + 4, "header + 2 schedules x 2 variability");
    let json = std::fs::read_to_string(out_dir.join("report.json")).unwrap();
    assert!(json.contains("\"variability\":\"hetero:1,1,2,4\""), "{json}");
}
