//! End-to-end tests over the PJRT runtime: artifacts -> compile ->
//! execute -> numerics vs the Python goldens, and the full scheduled
//! pipeline (E8's correctness half).
//!
//! These tests skip gracefully when `make artifacts` has not been run.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use uds::coordinator::{parallel_for, ExecOptions, HistoryArena, LoopSpec, TeamSpec};
use uds::runtime::{with_runtime, Golden, WorkRuntime};
use uds::schedules::ScheduleSpec;

fn artifacts_dir() -> Option<PathBuf> {
    if !uds::runtime::available() {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn all_depth_classes_match_goldens() {
    let dir = require_artifacts!();
    let rt = WorkRuntime::load(&dir).unwrap();
    let golden = Golden::load(&dir).unwrap();
    for rec in &golden.outputs {
        let out = rt
            .run_chunk(rec.depth, &golden.inputs.x, &golden.inputs.w, &golden.inputs.b)
            .unwrap();
        let sum: f64 = out.iter().map(|&v| v as f64).sum();
        let tol = 1e-3 * rec.abs_sum.max(1.0);
        assert!(
            (sum - rec.sum).abs() < tol,
            "depth {}: sum {sum} vs golden {} (tol {tol})",
            rec.depth,
            rec.sum
        );
    }
}

#[test]
fn outputs_bounded_by_tanh() {
    let dir = require_artifacts!();
    let rt = WorkRuntime::load(&dir).unwrap();
    let golden = Golden::load(&dir).unwrap();
    let out = rt
        .run_chunk(4, &golden.inputs.x, &golden.inputs.w, &golden.inputs.b)
        .unwrap();
    assert!(out.iter().all(|v| v.abs() <= 1.0 + 1e-6));
}

#[test]
fn deeper_work_costs_more_wall_time() {
    let dir = require_artifacts!();
    let rt = WorkRuntime::load(&dir).unwrap();
    let golden = Golden::load(&dir).unwrap();
    let time = |depth: u32, reps: u32| {
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            rt.run_chunk(depth, &golden.inputs.x, &golden.inputs.w, &golden.inputs.b)
                .unwrap();
        }
        t0.elapsed()
    };
    // Warm up both executables first.
    time(1, 3);
    time(8, 3);
    let shallow = time(1, 20);
    let deep = time(8, 20);
    // Depth 8 does 8x the matmuls of depth 1, but per-execute dispatch
    // overhead dominates this small (128x64) chunk on CPU PJRT, so the
    // measured wall ratio is ~1.7-2x (see EXPERIMENTS.md E8 calibration).
    // Insist on clear monotone separation, not the flop ratio.
    assert!(
        deep.as_secs_f64() > shallow.as_secs_f64() * 1.15,
        "depth 8 ({deep:?}) should cost >1.15x depth 1 ({shallow:?})"
    );
}

/// The E8 pipeline: scheduled execution of the real workload across a
/// thread team, every chunk verified against the depth-1 golden checksum.
#[test]
fn scheduled_pipeline_executes_all_work_items() {
    let dir = require_artifacts!();
    let golden = Golden::load(&dir).unwrap();
    let n_items = 48u64;
    let depths: Vec<u32> =
        (0..n_items).map(|i| [1u32, 1, 2, 1, 4, 1, 2, 8][i as usize % 8]).collect();
    let team = TeamSpec::uniform(4);
    for spec in [
        ScheduleSpec::Dynamic { chunk: 2 },
        ScheduleSpec::Guided { min_chunk: 1 },
        ScheduleSpec::Fac2,
    ] {
        let ok = AtomicU64::new(0);
        let history = HistoryArena::new();
        let stats = parallel_for(
            &LoopSpec::upto(n_items),
            &team,
            &*spec.factory(),
            &history,
            &ExecOptions::default(),
            |i, _tid| {
                let depth = depths[i as usize];
                let out = with_runtime(&dir, |rt| {
                    rt.run_chunk(depth, &golden.inputs.x, &golden.inputs.w, &golden.inputs.b)
                })
                .unwrap();
                // Verify numerics inline for depth classes with goldens.
                if let Some(rec) =
                    golden.outputs.iter().find(|r| r.depth == depth)
                {
                    let sum: f64 = out.iter().map(|&v| v as f64).sum();
                    assert!(
                        (sum - rec.sum).abs() < 1e-3 * rec.abs_sum.max(1.0),
                        "depth {depth} wrong checksum under {}",
                        spec.label()
                    );
                }
                ok.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(ok.load(Ordering::Relaxed), n_items, "{}", spec.label());
        assert_eq!(stats.iterations, n_items);
    }
}
