//! End-to-end BATCH sweep over TCP: the acceptance criteria of the
//! batch subsystem (ISSUE 3).
//!
//! * a ≥100-scenario grid streams one JSON record per scenario plus a
//!   terminal summary;
//! * the result stream is bit-identical for 1 vs 8 workers;
//! * each distinct workload's `CostIndex` is built at most once,
//!   asserted via the summary's cache-stat deltas.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use uds::eval::report::{parse_flat, ScenarioResult, SweepSummary};
use uds::service::serve_on;

fn spawn_service(pool_workers: usize) -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || serve_on(listener, pool_workers));
    addr
}

/// Send one line, collect the full response (until summary or ERR).
fn roundtrip(addr: std::net::SocketAddr, line: &str) -> Vec<String> {
    let mut c = TcpStream::connect(addr).unwrap();
    writeln!(c, "{line}").unwrap();
    let reader = BufReader::new(c.try_clone().unwrap());
    let mut out = Vec::new();
    for l in reader.lines() {
        let l = l.unwrap();
        let done = l.contains("\"type\":\"summary\"") || l.starts_with("ERR");
        out.push(l);
        if done {
            break;
        }
    }
    out
}

fn summary_of(lines: &[String]) -> SweepSummary {
    SweepSummary::from_flat(&parse_flat(lines.last().unwrap()).unwrap()).unwrap()
}

#[test]
fn batch_sweep_120_scenarios_streams_deterministically() {
    let addr = spawn_service(2);
    // workloads(2) x n(2) x seeds(1) x schedules(5) x threads(3) = 120.
    let grid = "BATCH workloads=lognormal,uniform \
schedules=fac2;gss;static;dynamic,16;tss n=500,1000 threads=2,4,8 seeds=1 \
workers=1";
    let one = roundtrip(addr, grid);
    assert_eq!(one.len(), 121, "120 results + summary");

    // Every record is valid flat JSON with dense, ordered ids.
    for (i, line) in one[..120].iter().enumerate() {
        let map = parse_flat(line).unwrap();
        assert_eq!(map.get("type").unwrap(), "result", "{line}");
        let rec = ScenarioResult::from_flat(&map).unwrap();
        assert_eq!(rec.id, i as u64);
        assert!(rec.makespan_ns > 0);
    }

    // Cold cache: exactly one build per distinct (workload, n) pair.
    let s1 = summary_of(&one);
    assert_eq!(s1.scenarios, 120);
    assert_eq!(s1.distinct_workloads, 4);
    assert_eq!(s1.index_builds, 4, "each distinct CostIndex built once");
    assert_eq!(s1.cache_hits, 120, "every scenario served from the cache");

    // Same grid, 8 workers, warm cache: bit-identical result stream,
    // zero rebuilds.
    let eight = roundtrip(addr, &grid.replace("workers=1", "workers=8"));
    assert_eq!(eight.len(), 121);
    assert_eq!(one[..120], eight[..120], "sharding must not change results");
    let s8 = summary_of(&eight);
    assert_eq!(s8.index_builds, 0, "warm cache rebuilds nothing");
    assert_eq!(s8.scenarios, 120);
}

#[test]
fn batch_errors_leave_connection_usable() {
    let addr = spawn_service(1);
    let mut c = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());
    let mut line = String::new();

    // Malformed framing answers one coded error line...
    writeln!(c, "BATCH schedules=fac2 n=not-a-number").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR bad_value"), "{line}");

    // ...and the same connection still serves single jobs and batches.
    writeln!(c, "schedule=gss n=200 threads=2 workload=uniform").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ok "), "{line}");

    writeln!(c, "BATCH schedules=fac2 n=200 workloads=uniform").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"type\":\"result\""), "{line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"type\":\"summary\""), "{line}");
}

#[test]
fn oversized_grid_rejected_up_front() {
    let addr = spawn_service(1);
    let ns: String =
        (1..=2000).map(|i| i.to_string()).collect::<Vec<_>>().join(",");
    let line = format!(
        "BATCH workloads=uniform,gaussian,lognormal,bimodal \
schedules=fac2;gss;static;dynamic,16 n={ns} seeds=1,2,3,4"
    );
    let resp = roundtrip(addr, &line);
    assert_eq!(resp.len(), 1);
    assert!(resp[0].starts_with("ERR grid_too_large"), "{}", resp[0]);
}
