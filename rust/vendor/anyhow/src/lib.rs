//! Minimal, std-only stand-in for the `anyhow` crate.
//!
//! This build environment is offline, so instead of pulling the real
//! crates.io `anyhow` we vendor the tiny API subset the `uds` crate
//! uses: [`Error`], [`Result`], the [`anyhow!`] macro and the
//! [`Context`] extension trait.  Semantics match the real crate for
//! that subset (errors are type-erased into a message chain; any
//! `std::error::Error` converts via `?`).

use std::fmt;

/// A type-erased error: a display message, optionally wrapped in
/// context layers (`"context: cause"`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` expands
    /// to).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }

    /// Wrap this error in a context layer.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors real anyhow: any std error converts via `?`.  (This is why
// `Error` itself must NOT implement `std::error::Error` — the blanket
// impl would otherwise overlap with `From<T> for T`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Attach context to a fallible computation (the `anyhow::Context`
/// extension trait, for `Result` and `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("x = {x}");
        assert_eq!(b.to_string(), "x = 7");
        let c = anyhow!("x = {}", x);
        assert_eq!(c.to_string(), "x = 7");
        let d = anyhow!(String::from("owned"));
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.with_context(|| "reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
        let none: Option<u8> = None;
        assert!(none.context("missing").is_err());
    }
}
