//! Sim-throughput bench: simulations/second before vs. after the
//! prefix-sum cost engine (EXPERIMENTS.md §Sim-throughput).
//!
//! Two call paths per schedule, same workload/geometry:
//!
//! * `per_run_materialize` — today's `simulate()` wrapper: every run
//!   pays the O(n) cost-table build (one RNG sample per iteration, the
//!   dominant pre-change cost) plus fresh arena allocation.  The
//!   pre-change code paid this *and* O(n) per-iteration summation
//!   inside the virtual-time loop, so the speedup this bench reports
//!   is a lower bound on the true before/after ratio.
//! * `cached_index` — the post-change hot path: the `CostIndex` is
//!   built once outside the timed region (exactly like the service's
//!   workload cache and the sweep drivers), the `SimArena` is reused,
//!   and each run is O(chunks).
//!
//! Run: `cargo bench --bench sim_throughput` (full: n=1e6, P=8) or
//! `cargo bench --bench sim_throughput -- --smoke` (CI-sized n=20k).
//! `--json PATH` additionally writes the measurements as a perf-gate
//! document (`uds perf-gate` compares it against `bench_baseline.json`);
//! the `calibration` entry is a fixed PRNG churn the gate uses to
//! cancel raw host speed.  The headline ratio is printed at the end and
//! recorded in EXPERIMENTS.md.

use uds::coordinator::{LoopRecord, LoopSpec, TeamSpec};
use uds::schedules::ScheduleSpec;
use uds::sim::{simulate, simulate_indexed, NoVariability, SimArena, SimConfig};
use uds::util::rng::Pcg;
use uds::util::Bench;
use uds::workload::{CostIndex, WorkloadClass};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path: Option<String> = args
        .iter()
        .position(|a| a == "--json")
        .map(|i| {
            args.get(i + 1)
                .unwrap_or_else(|| {
                    eprintln!("--json needs a path");
                    std::process::exit(2);
                })
                .clone()
        });
    let n: u64 = if smoke { 20_000 } else { 1_000_000 };
    let p = 8usize;
    let cfg = SimConfig { dequeue_overhead_ns: 250, trace: false };
    let class = WorkloadClass::Lognormal;
    let model = class.model(n, 1_000.0, 42);

    let group = if smoke { "sim_throughput_smoke" } else { "sim_throughput" };
    let mut g = Bench::group(group);
    if smoke {
        g.budget = std::time::Duration::from_millis(200);
        g.samples = 4;
    }

    // Fixed CPU-bound reference workload: the perf gate divides every
    // mean by this to cancel host speed across CI runners.
    let mut rng = Pcg::seed_from_u64(7);
    g.bench("calibration", || {
        let mut acc = 0u64;
        for _ in 0..100_000 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        acc
    });

    let mut pairs: Vec<(String, f64, f64)> = Vec::new();
    for name in ["fac2", "gss"] {
        let spec = ScheduleSpec::parse(name).unwrap();
        let factory = spec.factory();

        let before = g
            .bench(&format!("{name}/per_run_materialize"), || {
                simulate(
                    &LoopSpec::upto(n),
                    &TeamSpec::uniform(p),
                    &*factory,
                    &model,
                    &NoVariability,
                    &mut LoopRecord::default(),
                    &cfg,
                )
                .makespan_ns
            })
            .clone();

        let index = CostIndex::build(&model);
        let mut arena = SimArena::new();
        let after = g
            .bench(&format!("{name}/cached_index"), || {
                simulate_indexed(
                    &LoopSpec::upto(n),
                    &TeamSpec::uniform(p),
                    &*factory,
                    &index,
                    &NoVariability,
                    &mut LoopRecord::default(),
                    &cfg,
                    &mut arena,
                )
                .makespan_ns
            })
            .clone();

        // Sanity: both paths must simulate the identical physics.
        let a = simulate(
            &LoopSpec::upto(n),
            &TeamSpec::uniform(p),
            &*factory,
            &model,
            &NoVariability,
            &mut LoopRecord::default(),
            &cfg,
        );
        let b = simulate_indexed(
            &LoopSpec::upto(n),
            &TeamSpec::uniform(p),
            &*factory,
            &index,
            &NoVariability,
            &mut LoopRecord::default(),
            &cfg,
            &mut arena,
        );
        assert_eq!(a.makespan_ns, b.makespan_ns, "{name}: paths diverged");

        pairs.push((
            name.to_string(),
            before.mean.as_secs_f64(),
            after.mean.as_secs_f64(),
        ));
    }

    println!("\n== sims/second (n={n}, P={p}, lognormal, h=250ns) ==");
    for (name, before_s, after_s) in &pairs {
        let before_rate = 1.0 / before_s.max(1e-12);
        let after_rate = 1.0 / after_s.max(1e-12);
        let speedup = after_rate / before_rate.max(1e-12);
        println!(
            "{name:<6} before={before_rate:>12.1}/s  after={after_rate:>12.1}/s  \
speedup={speedup:.1}x"
        );
    }
    let _ = g.save_csv();
    if let Some(path) = json_path {
        let path = std::path::PathBuf::from(path);
        g.save_json(&path).expect("write bench json");
        println!("saved {}", path.display());
    }
}
