//! Sim-throughput bench: simulations/second before vs. after the
//! prefix-sum cost engine (EXPERIMENTS.md §Sim-throughput).
//!
//! Two call paths per schedule, same workload/geometry:
//!
//! * `per_run_materialize` — today's `simulate()` wrapper: every run
//!   pays the O(n) cost-table build (one RNG sample per iteration, the
//!   dominant pre-change cost) plus fresh arena allocation.  The
//!   pre-change code paid this *and* O(n) per-iteration summation
//!   inside the virtual-time loop, so the speedup this bench reports
//!   is a lower bound on the true before/after ratio.
//! * `cached_index` — the post-change hot path: the `CostIndex` is
//!   built once outside the timed region (exactly like the service's
//!   workload cache and the sweep drivers), the `SimArena` is reused,
//!   and each run is O(chunks).
//!
//! A third axis measures the batched SoA kernel: `batch/k{1,8,32}`
//! entries time one `simulate_batch` call over K lanes of the
//! cached-index sweep case (one shared `CostIndex`, fresh per-lane
//! records), so `mean_ns / K` is the per-scenario cost and the printed
//! scenarios/sec compares K values directly.  `uds perf-gate
//! --batch-min-speedup` enforces the K=32-vs-K=1 ratio.
//!
//! Run: `cargo bench --bench sim_throughput` (full: n=1e6, P=8) or
//! `cargo bench --bench sim_throughput -- --smoke` (CI-sized n=20k);
//! `--batch` restricts the run to calibration + the batch axis (the
//! quick kernel-only smoke case).
//! `--json PATH` additionally writes the measurements as a perf-gate
//! document (`uds perf-gate` compares it against `bench_baseline.json`);
//! the `calibration` entry is a fixed PRNG churn the gate uses to
//! cancel raw host speed.  The headline ratio is printed at the end and
//! recorded in EXPERIMENTS.md.

use uds::coordinator::{LoopRecord, LoopSpec, TeamSpec};
use uds::schedules::ScheduleSpec;
use uds::sim::{
    simulate, simulate_batch, simulate_indexed, BatchArena, BatchLane,
    NoVariability, SimArena, SimConfig,
};
use uds::util::rng::Pcg;
use uds::util::Bench;
use uds::workload::{CostIndex, WorkloadClass};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let batch_only = args.iter().any(|a| a == "--batch");
    let json_path: Option<String> = args
        .iter()
        .position(|a| a == "--json")
        .map(|i| {
            args.get(i + 1)
                .unwrap_or_else(|| {
                    eprintln!("--json needs a path");
                    std::process::exit(2);
                })
                .clone()
        });
    let n: u64 = if smoke { 20_000 } else { 1_000_000 };
    let p = 8usize;
    let cfg = SimConfig { dequeue_overhead_ns: 250, trace: false };
    let class = WorkloadClass::Lognormal;
    let model = class.model(n, 1_000.0, 42);

    let group = if smoke { "sim_throughput_smoke" } else { "sim_throughput" };
    let mut g = Bench::group(group);
    if smoke {
        g.budget = std::time::Duration::from_millis(200);
        g.samples = 4;
    }

    // Fixed CPU-bound reference workload: the perf gate divides every
    // mean by this to cancel host speed across CI runners.
    let mut rng = Pcg::seed_from_u64(7);
    g.bench("calibration", || {
        let mut acc = 0u64;
        for _ in 0..100_000 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        acc
    });

    let mut pairs: Vec<(String, f64, f64)> = Vec::new();
    let schedules: &[&str] = if batch_only { &[] } else { &["fac2", "gss"] };
    for &name in schedules {
        let spec = ScheduleSpec::parse(name).unwrap();
        let factory = spec.factory();

        let before = g
            .bench(&format!("{name}/per_run_materialize"), || {
                simulate(
                    &LoopSpec::upto(n),
                    &TeamSpec::uniform(p),
                    &*factory,
                    &model,
                    &NoVariability,
                    &mut LoopRecord::default(),
                    &cfg,
                )
                .makespan_ns
            })
            .clone();

        let index = CostIndex::build(&model);
        let mut arena = SimArena::new();
        let after = g
            .bench(&format!("{name}/cached_index"), || {
                simulate_indexed(
                    &LoopSpec::upto(n),
                    &TeamSpec::uniform(p),
                    &*factory,
                    &index,
                    &NoVariability,
                    &mut LoopRecord::default(),
                    &cfg,
                    &mut arena,
                )
                .makespan_ns
            })
            .clone();

        // Sanity: both paths must simulate the identical physics.
        let a = simulate(
            &LoopSpec::upto(n),
            &TeamSpec::uniform(p),
            &*factory,
            &model,
            &NoVariability,
            &mut LoopRecord::default(),
            &cfg,
        );
        let b = simulate_indexed(
            &LoopSpec::upto(n),
            &TeamSpec::uniform(p),
            &*factory,
            &index,
            &NoVariability,
            &mut LoopRecord::default(),
            &cfg,
            &mut arena,
        );
        assert_eq!(a.makespan_ns, b.makespan_ns, "{name}: paths diverged");

        pairs.push((
            name.to_string(),
            before.mean.as_secs_f64(),
            after.mean.as_secs_f64(),
        ));
    }

    // Batched SoA kernel axis: one simulate_batch call over K lanes of
    // the cached-index sweep case (fac2, shared index, fresh per-lane
    // records — what the sweep engine dispatches per seed block).
    let batch_spec = ScheduleSpec::parse("fac2").unwrap();
    let batch_factory = batch_spec.factory();
    let index = CostIndex::build(&model);
    let mut batch_arena = BatchArena::new();
    let mut batch_rates: Vec<(usize, f64)> = Vec::new();
    for k in [1usize, 8, 32] {
        let lanes: Vec<BatchLane> = (0..k)
            .map(|_| BatchLane { index: &index, var: &NoVariability })
            .collect();
        let m = g
            .bench(&format!("batch/k{k}"), || {
                let mut records: Vec<LoopRecord> =
                    (0..k).map(|_| LoopRecord::default()).collect();
                simulate_batch(
                    &LoopSpec::upto(n),
                    &TeamSpec::uniform(p),
                    &*batch_factory,
                    &lanes,
                    &mut records,
                    &cfg,
                    &mut batch_arena,
                )
                .last()
                .map(|s| s.makespan_ns)
                .unwrap_or(0)
            })
            .clone();
        batch_rates.push((k, k as f64 / m.mean.as_secs_f64().max(1e-12)));
    }

    // Sanity: every batch lane simulates the identical physics to the
    // scalar cached-index path.
    let mut sanity_arena = SimArena::new();
    let scalar_ref = simulate_indexed(
        &LoopSpec::upto(n),
        &TeamSpec::uniform(p),
        &*batch_factory,
        &index,
        &NoVariability,
        &mut LoopRecord::default(),
        &cfg,
        &mut sanity_arena,
    );
    let lanes = vec![BatchLane { index: &index, var: &NoVariability }; 4];
    let mut records: Vec<LoopRecord> =
        (0..4).map(|_| LoopRecord::default()).collect();
    let batch_ref = simulate_batch(
        &LoopSpec::upto(n),
        &TeamSpec::uniform(p),
        &*batch_factory,
        &lanes,
        &mut records,
        &cfg,
        &mut batch_arena,
    );
    for (l, s) in batch_ref.iter().enumerate() {
        assert_eq!(
            s.makespan_ns, scalar_ref.makespan_ns,
            "batch lane {l} diverged from scalar"
        );
    }

    if !pairs.is_empty() {
        println!("\n== sims/second (n={n}, P={p}, lognormal, h=250ns) ==");
        for (name, before_s, after_s) in &pairs {
            let before_rate = 1.0 / before_s.max(1e-12);
            let after_rate = 1.0 / after_s.max(1e-12);
            let speedup = after_rate / before_rate.max(1e-12);
            println!(
                "{name:<6} before={before_rate:>12.1}/s  after={after_rate:>12.1}/s  \
speedup={speedup:.1}x"
            );
        }
    }
    println!(
        "\n== batched kernel: scenarios/second (n={n}, P={p}, shared index, fac2) =="
    );
    for (k, rate) in &batch_rates {
        println!("k={k:<3} {rate:>12.1} scenarios/s");
    }
    if let (Some((_, r1)), Some((kmax, rmax))) = (
        batch_rates.iter().find(|(k, _)| *k == 1),
        batch_rates.last(),
    ) {
        println!(
            "batch k{kmax} vs k1 per-scenario speedup: {:.2}x",
            rmax / r1.max(1e-12)
        );
    }
    let _ = g.save_csv();
    if let Some(path) = json_path {
        let path = std::path::PathBuf::from(path);
        g.save_json(&path).expect("write bench json");
        println!("saved {}", path.display());
    }
}
