//! Benches regenerating the evaluation experiments E1–E7 on the
//! deterministic simulator (one bench per table/figure; E8's real PJRT
//! run lives in examples/xla_pipeline.rs and `uds eval e8`).
//!
//! These wrap the same `eval::eN` functions the CLI uses: running `cargo
//! bench --bench experiments` both times the harness and prints + saves
//! the tables recorded in EXPERIMENTS.md.

use uds::eval::{self, EvalConfig};
use uds::util::Bench;

fn cfg() -> EvalConfig {
    EvalConfig { n: 50_000, p: 8, mean_ns: 1_000.0, h_ns: 250, seed: 42 }
}

fn print_and_save_tables() {
    let c = cfg();
    for tables in [
        eval::e1(&c),
        eval::e2(&c),
        eval::e3(&c),
        eval::e4(&c),
        eval::e5(&c),
        eval::e6(&c),
        eval::e7(&c),
    ] {
        for t in tables {
            println!("{}", t.markdown());
            let _ = t.save_csv(std::path::Path::new("results"));
        }
    }
}

fn main() {
    print_and_save_tables();

    let conf = cfg();
    let mut g = Bench::group("experiments");
    g.budget = std::time::Duration::from_millis(1500);
    g.samples = 5;
    g.bench("e1_chunk_evolution", || eval::e1(&conf).len());
    g.bench("e2_e3_schedule_matrix", || eval::e2(&conf).len());
    g.bench("e4_chunk_sweep", || eval::e4(&conf).len());
    g.bench("e5_noise_adaptivity", || eval::e5(&conf).len());
    g.bench("e6_uds_equivalence", || eval::e6(&conf).len());
    g.bench("e7_heterogeneous", || eval::e7(&conf).len());
    let _ = g.save_csv();
}
