//! E6's performance half: what do the two proposed UDS frontends cost
//! relative to the native implementation of the same strategy?
//!
//! The paper argues (§4.3) that the lambda-style getters/setters are
//! free after inlining, while the declare style pays positional-argument
//! marshalling.  In this library the analogue is: native = direct trait
//! impl; lambda = closure dispatch + DequeueSink; declare = positional
//! out-params + logical-bound normalization.  EXPERIMENTS.md §Perf
//! records the measured ratios.

use uds::coordinator::declare::Registry;
use uds::coordinator::{LoopRecord, LoopSpec, ScheduleFactory, TeamSpec};
use uds::schedules::{uds_port, ScheduleSpec};
use uds::util::Bench;

fn drain(factory: &dyn ScheduleFactory, n: u64, p: usize) -> u64 {
    let mut s = factory.build();
    let loop_spec = LoopSpec::upto(n);
    let team = TeamSpec::uniform(p);
    let mut rec = LoopRecord::default();
    s.start(&loop_spec, &team, &mut rec);
    let mut count = 0u64;
    let mut live = vec![true; p];
    while live.iter().any(|&l| l) {
        for (tid, alive) in live.iter_mut().enumerate() {
            if *alive {
                match s.next(tid, None) {
                    Some(c) => count += c.len,
                    None => *alive = false,
                }
            }
        }
    }
    s.finish(&team, &mut rec);
    count
}

struct ArcFactory(std::sync::Arc<uds::coordinator::lambda::LambdaFactory>);

impl ScheduleFactory for ArcFactory {
    fn name(&self) -> String {
        ScheduleFactory::name(&*self.0)
    }
    fn build(&self) -> Box<dyn uds::coordinator::Scheduler> {
        self.0.build()
    }
}

fn main() {
    const N: u64 = 65_536;
    const P: usize = 8;
    let mut g = Bench::group("frontend_overhead_drain");
    let reg = Registry::new();

    // dynamic,16: the cheapest native dequeue (fetch_add) — worst case
    // for relative frontend overhead.
    let native = ScheduleSpec::Dynamic { chunk: 16 }.factory();
    g.bench("dynamic16/native", || drain(&*native, N, P));
    let lambda = ArcFactory(uds_port::lambda_dynamic(16));
    g.bench("dynamic16/lambda", || drain(&lambda, N, P));
    let declare = uds_port::declare_dynamic(&reg, 16);
    g.bench("dynamic16/declare", || drain(&declare, N, P));

    // guided: CAS-loop native.
    let native = ScheduleSpec::Guided { min_chunk: 1 }.factory();
    g.bench("guided/native", || drain(&*native, N, P));
    let lambda = ArcFactory(uds_port::lambda_gss(1));
    g.bench("guided/lambda", || drain(&lambda, N, P));
    let declare = uds_port::declare_gss(&reg);
    g.bench("guided/declare", || drain(&declare, N, P));

    // static,16: per-thread counters, zero sharing.
    let native = ScheduleSpec::Static { chunk: Some(16) }.factory();
    g.bench("static16/native", || drain(&*native, N, P));
    let lambda = ArcFactory(uds_port::lambda_static(16));
    g.bench("static16/lambda", || drain(&lambda, N, P));
    let declare = uds_port::declare_static(&reg, 16);
    g.bench("static16/declare", || drain(&declare, N, P));

    // fac2: compiled native vs the universal wrap_native adapter.
    let native = ScheduleSpec::Fac2.factory();
    g.bench("fac2/native", || drain(&*native, N, P));
    let wrapped = ArcFactory(uds_port::wrap_native("fac2", |_, _| {
        uds::schedules::fac2()
    }));
    g.bench("fac2/wrap_native", || drain(&wrapped, N, P));

    let _ = g.save_csv();
}
