//! Micro-benchmarks: per-dequeue cost of every strategy.
//!
//! This is the L3 hot path the paper's interface must not bloat: a
//! `next()` call on the contended todo list.  Results feed EXPERIMENTS.md
//! §Perf (native dequeue cost) and pair with `overhead.rs` (UDS frontend
//! cost on the same strategies).

use uds::coordinator::{parallel_for, ExecOptions, HistoryArena, LoopRecord, LoopSpec, TeamSpec};
use uds::schedules::ScheduleSpec;
use uds::util::Bench;

/// Drain an entire loop through `next` single-threaded: measures the
/// amortized dequeue cost without body or contention noise.
fn drain_cost(spec: &ScheduleSpec, n: u64, p: usize) -> u64 {
    let mut s = spec.build();
    let loop_spec = LoopSpec::upto(n);
    let team = TeamSpec::uniform(p);
    let mut rec = LoopRecord::default();
    s.start(&loop_spec, &team, &mut rec);
    let mut chunks = 0u64;
    let mut live = vec![true; p];
    while live.iter().any(|&l| l) {
        for (tid, alive) in live.iter_mut().enumerate() {
            if *alive {
                match s.next(tid, None) {
                    Some(_) => chunks += 1,
                    None => *alive = false,
                }
            }
        }
    }
    s.finish(&team, &mut rec);
    chunks
}

fn bench_dequeue_drain() {
    let mut g = Bench::group("dequeue_drain_n65536_p8");
    for spec in ScheduleSpec::roster() {
        g.bench(&spec.label(), || drain_cost(&spec, 65_536, 8));
    }
    // §Perf ablation: the compiled-boundary GSS variant that was tried
    // and reverted (slower at GSS's low dequeue counts; see gss.rs doc).
    g.bench("guided(compiled,ablation)", || {
        use uds::coordinator::Scheduler as _;
        let mut s = uds::schedules::GssCompiled::new(1);
        let loop_spec = LoopSpec::upto(65_536);
        let team = TeamSpec::uniform(8);
        let mut rec = LoopRecord::default();
        s.start(&loop_spec, &team, &mut rec);
        let mut chunks = 0u64;
        let mut live = vec![true; 8];
        while live.iter().any(|&l| l) {
            for (tid, alive) in live.iter_mut().enumerate() {
                if *alive {
                    match s.next(tid, None) {
                        Some(_) => chunks += 1,
                        None => *alive = false,
                    }
                }
            }
        }
        chunks
    });
    let _ = g.save_csv();
}

fn bench_start_cost() {
    // `start` builds the todo list: compiled schedules (TSS/FAC2) pay
    // their boundary precomputation here.
    let mut g = Bench::group("start_n1M_p8");
    let loop_spec = LoopSpec::upto(1_000_000);
    let team = TeamSpec::uniform(8);
    for spec in [
        ScheduleSpec::Static { chunk: None },
        ScheduleSpec::Dynamic { chunk: 16 },
        ScheduleSpec::Guided { min_chunk: 1 },
        ScheduleSpec::Tss { params: None },
        ScheduleSpec::Fac2,
    ] {
        g.bench(&spec.label(), || {
            let mut s = spec.build();
            let mut rec = LoopRecord::default();
            s.start(&loop_spec, &team, &mut rec);
            s.next(0, None)
        });
    }
    let _ = g.save_csv();
}

fn bench_contended() {
    // True multithreaded contention on the shared cursor: the fetch_add
    // hot path under P threads with an empty body.
    let mut g = Bench::group("contended_empty_body_n262144");
    for p in [2usize, 4, 8] {
        for spec in [
            ScheduleSpec::Dynamic { chunk: 1 },
            ScheduleSpec::Dynamic { chunk: 64 },
            ScheduleSpec::Guided { min_chunk: 1 },
            ScheduleSpec::Fac2,
            ScheduleSpec::Static { chunk: None },
            ScheduleSpec::StaticSteal { own_chunk: 64 },
        ] {
            let loop_spec = LoopSpec::upto(262_144);
            let team = TeamSpec::uniform(p);
            let history = HistoryArena::new();
            let factory = spec.factory();
            g.bench(&format!("{}_p{p}", spec.label()), || {
                parallel_for(
                    &loop_spec,
                    &team,
                    &*factory,
                    &history,
                    &ExecOptions::default(),
                    |_, _| {},
                )
                .chunks
            });
        }
    }
    let _ = g.save_csv();
}

fn main() {
    // `cargo bench -- <filter>` style: run groups matching any arg.
    let args: Vec<String> = std::env::args().skip(1).collect();
    let want = |name: &str| {
        args.iter().all(|a| a.starts_with('-'))
            || args.iter().any(|a| name.contains(a.as_str()))
    };
    if want("dequeue") {
        bench_dequeue_drain();
    }
    if want("start") {
        bench_start_cost();
    }
    if want("contended") {
        bench_contended();
    }
}
