//! The paper's Fig. 2, executable: implement `mystatic` (a naive
//! OpenMP-static clone) through the **declare-directive** UDS frontend
//! (§4.2), register it, and verify it produces exactly the chunks of the
//! native built-in `schedule(static,chunk)` — the paper's sufficiency
//! claim for one concrete strategy.
//!
//! Run: `cargo run --release --example declare_uds`

use std::sync::Mutex;

use uds::coordinator::declare::{Args, DeclarationBuilder, Registry};
use uds::coordinator::{
    drain_chunks, LoopRecord, LoopSpec, ScheduleFactory, TeamSpec,
};
use uds::schedules::StaticBlock;

/// The paper's `loop_record_t` (Fig. 2 right side).
#[derive(Default)]
struct LoopRecordT {
    lb: i64,
    ub: i64,
    incr: i64,
    chunksz: i64,
    next_lb: Vec<i64>,
}

fn main() {
    let reg = Registry::new();

    // #pragma omp declare schedule(mystatic) arguments(2) \
    //   init(mystatic_init(omp_lb, omp_ub, omp_incr, omp_chunksz, omp_arg0)) \
    //   next(mystatic_next(omp_lb_chunk, omp_ub_chunk, omp_chunk_incr, omp_arg0)) \
    //   fini(mystatic_fini(omp_arg0))
    reg.declare(
        DeclarationBuilder::schedule("mystatic")
            .arguments(2)
            .init(|lb, ub, incr, _chunk, nthreads, args| {
                let lr = args.arg::<Mutex<LoopRecordT>>(0);
                let chunksz = *args.arg::<i64>(1);
                let mut lr = lr.lock().unwrap();
                lr.lb = lb;
                lr.ub = ub;
                lr.incr = incr;
                lr.chunksz = chunksz;
                // lr->next_lb[tid] = lb + tid * chunksz  (Fig. 2)
                lr.next_lb =
                    (0..nthreads as i64).map(|t| lb + t * chunksz * incr).collect();
            })
            .next(|lower, upper, incr_out, tid, _fb, args| {
                let lr = args.arg::<Mutex<LoopRecordT>>(0);
                let mut lr = lr.lock().unwrap();
                if lr.next_lb[tid] >= lr.ub {
                    return false; // 0: loop completed
                }
                *lower = lr.next_lb[tid];
                let step = lr.chunksz * lr.incr;
                *upper = (lr.next_lb[tid] + step).min(lr.ub);
                *incr_out = lr.incr;
                // lr->next_lb[tid] += nthreads * chunksz  (round robin)
                let p = lr.next_lb.len() as i64;
                lr.next_lb[tid] += p * step;
                true
            })
            .fini(|args| {
                // the paper's free(lr->next_lb)
                let lr = args.arg::<Mutex<LoopRecordT>>(0);
                lr.lock().unwrap().next_lb.clear();
                println!("mystatic_fini: released todo list");
            })
            .build(),
    )
    .expect("declare mystatic");

    println!("declared schedules: {:?}", reg.names());

    // Use site: #pragma omp parallel for schedule(mystatic(&lr))
    let chunksz = 16i64;
    let factory = reg
        .schedule(
            "mystatic",
            Args::new().with(Mutex::new(LoopRecordT::default())).with(chunksz),
        )
        .expect("bind arguments");

    let spec = LoopSpec::upto(1000);
    let team = TeamSpec::uniform(4);

    let mut declared = factory.build();
    let declared_chunks =
        drain_chunks(&mut *declared, &spec, &team, &mut LoopRecord::default());

    // The native built-in it re-implements.
    let mut native = StaticBlock::new(Some(chunksz as u64));
    let native_chunks =
        drain_chunks(&mut native, &spec, &team, &mut LoopRecord::default());

    assert_eq!(declared_chunks, native_chunks);
    println!(
        "mystatic (declare-style UDS) == native static,{chunksz}: {} identical chunks ✓",
        declared_chunks.len()
    );

    // Show the first few chunks, as the paper's figure caption would.
    println!("\nfirst chunks (tid, [start, end)):");
    for (tid, c) in declared_chunks.iter().take(8) {
        println!("  t{tid}: [{:>4}, {:>4})", c.first, c.end());
    }
}
