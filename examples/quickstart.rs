//! Quickstart: schedule a parallel loop with built-in strategies, then
//! define your own schedule two ways — the paper's §4.1 lambda style and
//! a custom closure — and run them through the same executor.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::atomic::{AtomicU64, Ordering};

use uds::coordinator::lambda::UdsBuilder;
use uds::coordinator::{
    parallel_for, ExecOptions, HistoryArena, LoopSpec, TeamSpec,
};
use uds::schedules::ScheduleSpec;

fn main() {
    let n = 1_000_000u64;
    let spec = LoopSpec::upto(n);
    let team = TeamSpec::uniform(8);
    let history = HistoryArena::new();

    println!("== built-in schedules on sum(0..{n}) ==");
    let expected: u64 = n * (n - 1) / 2;
    for name in ["static", "dynamic,1024", "guided", "tss", "fac2", "awf-c"] {
        let sched = ScheduleSpec::parse(name).unwrap();
        let sum = AtomicU64::new(0);
        let stats = parallel_for(
            &spec,
            &team,
            &*sched.factory(),
            &history,
            &ExecOptions::default(),
            |i, _tid| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            },
        );
        assert_eq!(sum.into_inner(), expected);
        println!(
            "  {:<14} makespan={:>10} chunks={:<6} dequeues={:<6} imbalance={:.1}%",
            stats.schedule,
            format!("{:.2}ms", stats.makespan_ns as f64 / 1e6),
            stats.chunks,
            stats.total_dequeues(),
            stats.percent_imbalance()
        );
    }

    // ---- a user-defined schedule, lambda style (the paper's §4.1) ----
    //
    // "every thread takes exponentially shrinking chunks from its OWN
    // half, then falls back to a shared tail" — a strategy no standard
    // schedule() clause expresses.
    println!("\n== user-defined schedule (lambda style) ==");
    use std::sync::atomic::AtomicI64;
    let my_sched = UdsBuilder::named("half_and_tail")
        .chunk_size(64)
        .init(|ctx| {
            // State: per-thread cursor over its own block + shared tail.
            let p = ctx.num_threads() as u64;
            let n = ctx.iter_count();
            let own = n / 2 / p; // each thread privately owns n/2/p
            let cursors: Vec<AtomicI64> =
                (0..p).map(|t| AtomicI64::new((t * own) as i64)).collect();
            let ends: Vec<i64> = (0..p).map(|t| ((t + 1) * own) as i64).collect();
            let tail = AtomicI64::new((p * own) as i64);
            Box::new((cursors, ends, tail))
        })
        .dequeue(|ctx, state, tid, _fb, sink| {
            let (cursors, ends, tail) = state
                .downcast_ref::<(Vec<AtomicI64>, Vec<i64>, AtomicI64)>()
                .unwrap();
            let n = ctx.iter_count() as i64;
            // 1) shrink-take from own block
            let cur = cursors[tid].load(Ordering::Relaxed);
            if cur < ends[tid] {
                let left = ends[tid] - cur;
                let take = (left / 2).max(1);
                cursors[tid].store(cur + take, Ordering::Relaxed);
                sink.chunk_start(cur);
                sink.chunk_end(cur + take);
                return;
            }
            // 2) shared tail, fixed chunks
            let k = ctx.chunk_size() as i64;
            let first = tail.fetch_add(k, Ordering::Relaxed);
            if first >= n {
                sink.dequeue_done();
                return;
            }
            sink.chunk_start(first);
            sink.chunk_end((first + k).min(n));
        })
        .finalize(|_ctx, _state| println!("  half_and_tail: finalize called"))
        .build();

    let count = AtomicU64::new(0);
    let stats = parallel_for(
        &spec,
        &team,
        &*my_sched,
        &history,
        &ExecOptions::default(),
        |_i, _tid| {
            count.fetch_add(1, Ordering::Relaxed);
        },
    );
    assert_eq!(count.into_inner(), n);
    println!(
        "  {:<14} makespan={:>10} chunks={}",
        stats.schedule,
        format!("{:.2}ms", stats.makespan_ns as f64 / 1e6),
        stats.chunks
    );
    println!("\nall iterations executed exactly once under every schedule ✓");
}
