//! Adaptive scheduling under system-induced variability (the E5 story,
//! interactive): run a time-stepped "simulation" whose loop is scheduled
//! by static / guided / FAC2 / AWF-B on a machine with injected OS-noise
//! bursts and one permanently slow core, and watch the adaptive schedule
//! learn across invocations while the static one keeps paying.
//!
//! Run: `cargo run --release --example adaptive_noise`

use uds::coordinator::{LoopRecord, LoopSpec, TeamSpec};
use uds::schedules::ScheduleSpec;
use uds::sim::{simulate, Compose, Heterogeneous, NoiseBursts, SimConfig};
use uds::workload::WorkloadClass;

fn main() {
    let n = 100_000u64;
    let p = 8usize;
    let timesteps = 8;
    let costs = WorkloadClass::Gaussian.model(n, 1_000.0, 42);

    // The machine: core 5 runs at 40% speed (power-capped), plus random
    // noise bursts slowing any core to 30% for ~200us windows.
    let mut speeds = vec![1.0; p];
    speeds[5] = 0.4;
    let machine = Compose(
        Heterogeneous::new(speeds),
        NoiseBursts::new(200_000, 0.15, 0.3, 7),
    );
    let sim_cfg = SimConfig { dequeue_overhead_ns: 250, trace: false };

    let schedules = ["static", "guided", "fac2", "awf-b", "af"];
    println!(
        "time-stepped loop (N={n}, P={p}) on a noisy machine with one slow core"
    );
    println!("makespan per timestep (ms):\n");
    print!("{:>10}", "timestep");
    for s in &schedules {
        print!("{s:>10}");
    }
    println!();

    let mut records: Vec<LoopRecord> =
        schedules.iter().map(|_| LoopRecord::default()).collect();
    let mut totals = vec![0u64; schedules.len()];

    for step in 0..timesteps {
        print!("{step:>10}");
        for (si, name) in schedules.iter().enumerate() {
            let spec = ScheduleSpec::parse(name).unwrap();
            let stats = simulate(
                &LoopSpec::upto(n),
                &TeamSpec::uniform(p),
                &*spec.factory(),
                &costs,
                &machine,
                &mut records[si],
                &sim_cfg,
            );
            totals[si] += stats.makespan_ns;
            print!("{:>10.2}", stats.makespan_ns as f64 / 1e6);
        }
        println!();
    }

    println!("\ntotal wall time across {timesteps} timesteps:");
    let static_total = totals[0];
    for (si, name) in schedules.iter().enumerate() {
        println!(
            "  {:<8} {:>8.1} ms   ({:.2}x vs static)",
            name,
            totals[si] as f64 / 1e6,
            static_total as f64 / totals[si] as f64
        );
    }

    // AWF-B must have learned the slow core: its final weights should
    // give core 5 well under the average share.
    let awf_idx = schedules.iter().position(|s| *s == "awf-b").unwrap();
    let weights = &records[awf_idx].weights;
    println!("\nAWF-B learned weights: {:?}", weights
        .iter()
        .map(|w| (w * 100.0).round() / 100.0)
        .collect::<Vec<_>>());
    assert!(
        weights[5] < 0.8,
        "AWF should down-weight the slow core (got {})",
        weights[5]
    );
    assert!(
        totals[awf_idx] < static_total,
        "adaptive should beat static on a noisy machine"
    );
    println!("adaptive schedule beat static and identified the slow core ✓");
}
