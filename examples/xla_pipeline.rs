//! End-to-end driver (E8): the full three-layer stack on a real
//! workload.
//!
//!   L1  Pallas `dense_tanh` kernel        (python/compile/kernels/)
//!   L2  depth-k `work_chunk` jax graph    (python/compile/model.py)
//!       -> AOT-lowered once to HLO text   (make artifacts)
//!   L3  THIS: the Rust UDS runtime schedules an irregular stream of
//!       depth-mixed work items; each item executes the matching
//!       PJRT-compiled executable.  Python is not running.
//!
//! Two phases (this testbed has a single CPU core, so real threads
//! cannot show parallel speedup by construction):
//!
//!   1. REAL: execute all items through PJRT on a persistent thread
//!      team, verify every output against the Python-side goldens, and
//!      calibrate the measured per-depth chunk cost.
//!   2. SIM: replay the identical workload through the deterministic
//!      virtual-time executor with the measured costs on 8 virtual
//!      workers, reporting the schedule comparison the paper's
//!      evaluation shape calls for.
//!
//! Run: `make artifacts && cargo run --release --example xla_pipeline`

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use uds::coordinator::{
    HistoryArena, LoopRecord, LoopSpec, PersistentTeam, ScheduleFactory, TeamSpec,
};
use uds::runtime::{with_runtime, Golden, WorkRuntime};
use uds::schedules::ScheduleSpec;
use uds::sim::{simulate, NoVariability, SimConfig};
use uds::util::rng::Pcg;
use uds::workload::TraceCost;

fn main() {
    // Silence PJRT client lifecycle info-logs (read at absl init).
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    let artifacts = PathBuf::from(
        std::env::var("UDS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if !uds::runtime::available() {
        eprintln!(
            "PJRT backend unavailable — rebuild with `--features pjrt` \
             after adding the `xla` dependency (see rust/Cargo.toml)"
        );
        std::process::exit(1);
    }
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }

    // Probe the runtime once on the main thread for reporting.
    let rt = WorkRuntime::load(&artifacts).expect("load artifacts");
    println!(
        "PJRT platform: {} | depth classes: {:?} | chunk: {}x{}",
        rt.platform(),
        rt.depths(),
        rt.manifest.chunk_rows,
        rt.manifest.feature_dim
    );
    let golden = Arc::new(Golden::load(&artifacts).expect("golden.txt"));
    drop(rt);

    // The irregular workload: 512 items at a CLUSTERED depth mix —
    // cheap front, expensive tail with jitter (adaptive-mesh shape).
    let n_items = 512u64;
    let mut rng = Pcg::seed_from_u64(0xE8);
    let depths: Arc<Vec<u32>> = Arc::new(
        (0..n_items)
            .map(|i| {
                let f = i as f64 / n_items as f64 + rng.f64() * 0.05;
                if f < 0.60 {
                    1
                } else if f < 0.80 {
                    2
                } else if f < 0.92 {
                    4
                } else {
                    8
                }
            })
            .collect(),
    );
    let total_depth: u64 = depths.iter().map(|&d| d as u64).sum();
    let hw = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let real_p = hw.min(8);
    println!(
        "workload: {n_items} items, total depth {total_depth} (clustered 1..8 mix)\n\
         hardware threads: {hw} -> real team P={real_p}, simulated team P=8\n"
    );

    // ---- Phase 1: real execution, verification, calibration ----
    let team = PersistentTeam::new(TeamSpec::uniform(real_p));
    let history = HistoryArena::new();
    let dir = Arc::new(artifacts.clone());
    // Warm-up: compile all executables on every worker before timing.
    {
        let golden = golden.clone();
        let dir = dir.clone();
        team.parallel_for(
            &LoopSpec::upto(real_p as u64 * 4),
            &*ScheduleSpec::Static { chunk: Some(1) }.factory(),
            &history,
            None,
            Arc::new(move |i, _| {
                let d = [1u32, 2, 4, 8][i as usize % 4];
                let _ = with_runtime(&dir, |rt| {
                    rt.run_chunk(d, &golden.inputs.x, &golden.inputs.w, &golden.inputs.b)
                });
            }),
        );
    }

    let depth_times: Arc<Mutex<HashMap<u32, (u64, u64)>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let verified = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    {
        let depths = depths.clone();
        let golden = golden.clone();
        let dir = dir.clone();
        let depth_times = depth_times.clone();
        let verified = verified.clone();
        team.parallel_for(
            &LoopSpec::upto(n_items),
            &*ScheduleSpec::Dynamic { chunk: 4 }.factory(),
            &history,
            None,
            Arc::new(move |i, _tid| {
                let depth = depths[i as usize];
                let c0 = Instant::now();
                let out = with_runtime(&dir, |rt| {
                    rt.run_chunk(depth, &golden.inputs.x, &golden.inputs.w, &golden.inputs.b)
                })
                .expect("PJRT execution");
                let dt = c0.elapsed().as_nanos() as u64;
                let rec = golden.record(depth).expect("golden record");
                let sum: f64 = out.iter().map(|&v| v as f64).sum();
                assert!(
                    (sum - rec.sum).abs() < 1e-3 * rec.abs_sum.max(1.0),
                    "depth {depth} checksum mismatch"
                );
                verified.fetch_add(1, Ordering::Relaxed);
                let mut m = depth_times.lock().unwrap();
                let e = m.entry(depth).or_insert((0, 0));
                e.0 += dt;
                e.1 += 1;
            }),
        );
    }
    let real_wall = t0.elapsed();
    assert_eq!(verified.load(Ordering::Relaxed), n_items);
    println!(
        "phase 1 (real PJRT): {n_items} items executed + verified vs Python goldens \
         in {:.1} ms ({:.0} items/s on {real_p} worker(s))",
        real_wall.as_secs_f64() * 1e3,
        n_items as f64 / real_wall.as_secs_f64()
    );
    let mean_cost: HashMap<u32, u64> = depth_times
        .lock()
        .unwrap()
        .iter()
        .map(|(&d, &(tot, cnt))| (d, tot / cnt.max(1)))
        .collect();
    let mut ds: Vec<_> = mean_cost.iter().collect();
    ds.sort();
    println!("measured per-depth chunk cost:");
    for (d, ns) in ds {
        println!("  depth {d}: {:.1} us", *ns as f64 / 1e3);
    }

    // ---- Phase 2: simulated scheduling with measured costs ----
    let costs = TraceCost::new(depths.iter().map(|d| mean_cost[d]).collect());
    let schedules = [
        "static", "static,4", "dynamic,4", "guided", "fac2", "awf-c",
        "static_steal,4",
    ];
    println!("\nphase 2 (simulated, P=8 virtual workers, measured costs):");
    println!(
        "{:<16} {:>12} {:>10} {:>12}",
        "schedule", "makespan ms", "chunks", "vs static"
    );
    let mut static_ms = None;
    let mut best: Option<(String, f64)> = None;
    for name in schedules {
        let spec = ScheduleSpec::parse(name).unwrap();
        let stats = simulate(
            &LoopSpec::upto(n_items),
            &TeamSpec::uniform(8),
            &*spec.factory(),
            &costs,
            &NoVariability,
            &mut LoopRecord::default(),
            &SimConfig { dequeue_overhead_ns: 2_000, trace: false },
        );
        if name == "static" {
            static_ms = Some(stats.makespan_ns);
        }
        let speedup = static_ms.unwrap() as f64 / stats.makespan_ns as f64;
        if name != "static" {
            match &best {
                Some((_, b)) if *b >= speedup => {}
                _ => best = Some((name.to_string(), speedup)),
            }
        }
        println!(
            "{:<16} {:>12.2} {:>10} {:>11.2}x",
            name,
            stats.makespan_ns as f64 / 1e6,
            stats.chunks,
            speedup
        );
    }

    let (best_name, best_speedup) = best.unwrap();
    println!(
        "\nheadline: best dynamic schedule ({best_name}) = {best_speedup:.2}x vs static \
         on the clustered depth mix (measured per-depth costs; numerics verified) ✓"
    );
    println!("(recorded in EXPERIMENTS.md E8)");
}
